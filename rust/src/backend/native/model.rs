//! Native model definition: config, FP32 checkpoint, seeded init and a
//! self-describing binary checkpoint format.
//!
//! The architecture is the paper's LLaMA-family backbone (RMSNorm →
//! RoPE attention with grouped-query KV heads → SwiGLU MLP), i.e. the
//! same block structure `config::ModelSpec::linear_shapes` models, sized
//! down so a checkpoint quantizes in milliseconds at startup.
//!
//! [`NativeCheckpoint::seeded`] plants *outlier features*: a fixed stride
//! of embedding columns is scaled by [`OUTLIER_BOOST`], giving the
//! residual stream the heavy-tailed per-feature distribution that QUIK's
//! outlier split exploits (paper §3.2, Fig. 3).  Without that structure a
//! random model has no outliers to extract and INT4 range is wasted on
//! uniform noise; with it, the golden parity test can demand exact greedy
//! agreement between the FP32 reference and the QUIK-4B stack.

use std::fs;
use std::path::Path;

use anyhow::{bail, Context, Result};

use crate::util::rng::Rng;

/// Embedding columns `c` with `c % OUTLIER_STRIDE == OUTLIER_PHASE` are
/// boosted — the planted outlier features of seeded checkpoints.
pub const OUTLIER_STRIDE: usize = 6;
pub const OUTLIER_PHASE: usize = 5;
pub const OUTLIER_BOOST: f32 = 16.0;

/// Architecture of a native checkpoint (LLaMA-style block).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct NativeConfig {
    pub vocab: usize,
    pub d_model: usize,
    pub n_layers: usize,
    pub n_heads: usize,
    /// Key/value heads (< `n_heads` for grouped-query attention).
    pub n_kv_heads: usize,
    pub d_ff: usize,
    pub max_seq: usize,
}

impl NativeConfig {
    pub fn d_head(&self) -> usize {
        self.d_model / self.n_heads
    }

    pub fn kv_dim(&self) -> usize {
        self.n_kv_heads * self.d_head()
    }

    /// The equivalent paper-scale [`crate::config::ModelSpec`] (this is a
    /// LLaMA-family block by construction), connecting a native
    /// checkpoint to the byte-exact [`crate::memmodel`] accounting — the
    /// continuous engine uses it to autoscale slot counts against a
    /// memory budget.
    pub fn to_spec(&self) -> crate::config::ModelSpec {
        crate::config::ModelSpec {
            family: crate::config::Family::Llama,
            d_model: self.d_model,
            n_layers: self.n_layers,
            n_heads: self.n_heads,
            n_kv_heads: self.n_kv_heads,
            d_ff: self.d_ff,
            vocab: self.vocab,
            max_seq: self.max_seq,
        }
    }

    /// The demo/golden-test architecture: small enough that startup
    /// quantization and CI serving runs take milliseconds, large enough
    /// to exercise GQA, multi-layer residual flow and outlier selection.
    pub fn demo() -> Self {
        Self {
            vocab: 96,
            d_model: 48,
            n_layers: 2,
            n_heads: 4,
            n_kv_heads: 2,
            d_ff: 128,
            max_seq: 96,
        }
    }

    pub fn validate(&self) -> Result<()> {
        if self.vocab == 0 || self.d_model == 0 || self.n_layers == 0 || self.max_seq == 0 {
            bail!("config has a zero dimension: {self:?}");
        }
        if self.n_heads == 0 || self.d_model % self.n_heads != 0 {
            bail!("d_model {} not divisible by n_heads {}", self.d_model, self.n_heads);
        }
        if self.d_head() % 2 != 0 {
            bail!("d_head {} must be even for RoPE", self.d_head());
        }
        if self.n_kv_heads == 0 || self.n_heads % self.n_kv_heads != 0 {
            bail!("n_heads {} not divisible by n_kv_heads {}", self.n_heads, self.n_kv_heads);
        }
        Ok(())
    }
}

/// One transformer block's FP32 weights (all matrices `[out, in]` row-major).
#[derive(Debug, Clone)]
pub struct LayerWeights {
    pub attn_norm: Vec<f32>, // [d_model]
    pub wq: Vec<f32>,        // [d_model, d_model]
    pub wk: Vec<f32>,        // [kv_dim, d_model]
    pub wv: Vec<f32>,        // [kv_dim, d_model]
    pub wo: Vec<f32>,        // [d_model, d_model]
    pub mlp_norm: Vec<f32>,  // [d_model]
    pub w_gate: Vec<f32>,    // [d_ff, d_model]
    pub w_up: Vec<f32>,      // [d_ff, d_model]
    pub w_down: Vec<f32>,    // [d_model, d_ff]
}

/// A full FP32 checkpoint: what `quantize_weights`/`outlier` consume at
/// backend startup and what the FP32 reference variant serves directly.
#[derive(Debug, Clone)]
pub struct NativeCheckpoint {
    pub config: NativeConfig,
    pub embedding: Vec<f32>,  // [vocab, d_model]
    pub layers: Vec<LayerWeights>,
    pub final_norm: Vec<f32>, // [d_model]
    pub lm_head: Vec<f32>,    // [vocab, d_model]
}

fn normal_vec(rng: &mut Rng, n: usize, scale: f32) -> Vec<f32> {
    (0..n).map(|_| rng.normal() * scale).collect()
}

impl NativeCheckpoint {
    /// Deterministic random checkpoint with planted outlier features.
    ///
    /// The draw order (embedding, then per layer wq/wk/wv/wo/w_gate/w_up/
    /// w_down, then lm_head) is part of the golden-test contract — the
    /// parity vectors were produced by an independent mirror of exactly
    /// this sequence.
    pub fn seeded(config: NativeConfig, seed: u64) -> Self {
        let d = config.d_model;
        let kv = config.kv_dim();
        let ff = config.d_ff;
        let mut rng = Rng::new(seed);
        let sd = (1.0 / (d as f64).sqrt()) as f32;
        let sff = (1.0 / (ff as f64).sqrt()) as f32;

        let mut embedding = Vec::with_capacity(config.vocab * d);
        for i in 0..config.vocab * d {
            let mut v = rng.normal() * 0.1;
            if (i % d) % OUTLIER_STRIDE == OUTLIER_PHASE {
                v *= OUTLIER_BOOST;
            }
            embedding.push(v);
        }

        let mut layers = Vec::with_capacity(config.n_layers);
        for _ in 0..config.n_layers {
            layers.push(LayerWeights {
                attn_norm: vec![1.0; d],
                wq: normal_vec(&mut rng, d * d, sd),
                wk: normal_vec(&mut rng, kv * d, sd),
                wv: normal_vec(&mut rng, kv * d, sd),
                wo: normal_vec(&mut rng, d * d, sd),
                mlp_norm: vec![1.0; d],
                w_gate: normal_vec(&mut rng, ff * d, sd),
                w_up: normal_vec(&mut rng, ff * d, sd),
                w_down: normal_vec(&mut rng, d * ff, sff),
            });
        }

        Self {
            config,
            embedding,
            layers,
            final_norm: vec![1.0; d],
            lm_head: normal_vec(&mut rng, config.vocab * d, sd),
        }
    }

    /// Total FP32 bytes of the backbone linear weights (the tensors the
    /// QUIK stack replaces — norms/embeddings/head stay FP32 either way).
    pub fn linear_bytes(&self) -> usize {
        self.layers
            .iter()
            .map(|l| {
                4 * (l.wq.len()
                    + l.wk.len()
                    + l.wv.len()
                    + l.wo.len()
                    + l.w_gate.len()
                    + l.w_up.len()
                    + l.w_down.len())
            })
            .sum()
    }

    /// Tensors in serialization order (shared by save/load).
    fn tensor_lens(config: &NativeConfig) -> Vec<usize> {
        let d = config.d_model;
        let kv = config.kv_dim();
        let ff = config.d_ff;
        let mut lens = vec![config.vocab * d];
        for _ in 0..config.n_layers {
            lens.extend([d, d * d, kv * d, kv * d, d * d, d, ff * d, ff * d, d * ff]);
        }
        lens.push(d);
        lens.push(config.vocab * d);
        lens
    }

    /// Write the checkpoint: magic, 7×u32 config, then raw f32 LE tensors.
    pub fn save(&self, path: impl AsRef<Path>) -> Result<()> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);
        for v in [
            self.config.vocab,
            self.config.d_model,
            self.config.n_layers,
            self.config.n_heads,
            self.config.n_kv_heads,
            self.config.d_ff,
            self.config.max_seq,
        ] {
            out.extend_from_slice(&(v as u32).to_le_bytes());
        }
        for t in self.tensors() {
            for x in t {
                out.extend_from_slice(&x.to_le_bytes());
            }
        }
        fs::write(path.as_ref(), &out)
            .with_context(|| format!("writing checkpoint {:?}", path.as_ref()))
    }

    /// Load a checkpoint written by [`NativeCheckpoint::save`].
    pub fn load(path: impl AsRef<Path>) -> Result<Self> {
        let blob = fs::read(path.as_ref())
            .with_context(|| format!("reading checkpoint {:?}", path.as_ref()))?;
        if blob.len() < MAGIC.len() + 28 || &blob[..MAGIC.len()] != MAGIC {
            bail!("not a QUIK native checkpoint (bad magic)");
        }
        let mut off = MAGIC.len();
        let mut next_u32 = |blob: &[u8]| -> usize {
            let v = u32::from_le_bytes(blob[off..off + 4].try_into().unwrap()) as usize;
            off += 4;
            v
        };
        let config = NativeConfig {
            vocab: next_u32(&blob),
            d_model: next_u32(&blob),
            n_layers: next_u32(&blob),
            n_heads: next_u32(&blob),
            n_kv_heads: next_u32(&blob),
            d_ff: next_u32(&blob),
            max_seq: next_u32(&blob),
        };
        config.validate()?;
        let lens = Self::tensor_lens(&config);
        let need: usize = off + 4 * lens.iter().sum::<usize>();
        if blob.len() != need {
            bail!("checkpoint size mismatch: have {} bytes, need {need}", blob.len());
        }
        let mut read_f32s = |n: usize| -> Vec<f32> {
            let v = blob[off..off + 4 * n]
                .chunks_exact(4)
                .map(|b| f32::from_le_bytes(b.try_into().unwrap()))
                .collect();
            off += 4 * n;
            v
        };
        let embedding = read_f32s(config.vocab * config.d_model);
        let mut layers = Vec::with_capacity(config.n_layers);
        let d = config.d_model;
        let kv = config.kv_dim();
        let ff = config.d_ff;
        for _ in 0..config.n_layers {
            layers.push(LayerWeights {
                attn_norm: read_f32s(d),
                wq: read_f32s(d * d),
                wk: read_f32s(kv * d),
                wv: read_f32s(kv * d),
                wo: read_f32s(d * d),
                mlp_norm: read_f32s(d),
                w_gate: read_f32s(ff * d),
                w_up: read_f32s(ff * d),
                w_down: read_f32s(d * ff),
            });
        }
        let final_norm = read_f32s(d);
        let lm_head = read_f32s(config.vocab * d);
        Ok(Self { config, embedding, layers, final_norm, lm_head })
    }

    /// All tensors in serialization order.
    fn tensors(&self) -> Vec<&[f32]> {
        let mut v: Vec<&[f32]> = vec![&self.embedding];
        for l in &self.layers {
            v.extend([
                l.attn_norm.as_slice(),
                &l.wq,
                &l.wk,
                &l.wv,
                &l.wo,
                &l.mlp_norm,
                &l.w_gate,
                &l.w_up,
                &l.w_down,
            ]);
        }
        v.push(&self.final_norm);
        v.push(&self.lm_head);
        v
    }
}

const MAGIC: &[u8; 8] = b"QUIKNAT1";

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn demo_config_is_valid() {
        let c = NativeConfig::demo();
        c.validate().unwrap();
        assert_eq!(c.d_head(), 12);
        assert_eq!(c.kv_dim(), 24);
    }

    #[test]
    fn seeded_is_deterministic_and_planted() {
        let c = NativeConfig::demo();
        let a = NativeCheckpoint::seeded(c, 5);
        let b = NativeCheckpoint::seeded(c, 5);
        assert_eq!(a.embedding, b.embedding);
        assert_eq!(a.layers[1].w_down, b.layers[1].w_down);
        assert_eq!(a.lm_head, b.lm_head);
        // planted outlier columns dominate the embedding's column norms
        let d = c.d_model;
        let col_linf = |col: usize| -> f32 {
            (0..c.vocab).map(|r| a.embedding[r * d + col].abs()).fold(0f32, f32::max)
        };
        assert!(col_linf(OUTLIER_PHASE) > 4.0 * col_linf(0));
    }

    #[test]
    fn checkpoint_roundtrip() {
        let c = NativeConfig { vocab: 16, d_model: 8, n_layers: 1, n_heads: 2, n_kv_heads: 1, d_ff: 12, max_seq: 16 };
        let ck = NativeCheckpoint::seeded(c, 3);
        let path = std::env::temp_dir().join("quik_native_ckpt_test.bin");
        ck.save(&path).unwrap();
        let back = NativeCheckpoint::load(&path).unwrap();
        assert_eq!(back.config, c);
        assert_eq!(back.embedding, ck.embedding);
        assert_eq!(back.layers[0].w_up, ck.layers[0].w_up);
        assert_eq!(back.lm_head, ck.lm_head);
        std::fs::remove_file(&path).ok();
    }

    #[test]
    fn load_rejects_garbage() {
        let path = std::env::temp_dir().join("quik_native_bad_ckpt.bin");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(NativeCheckpoint::load(&path).is_err());
        std::fs::remove_file(&path).ok();
    }
}
