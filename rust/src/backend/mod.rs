//! Execution-backend abstraction for the serving stack.
//!
//! The coordinator (batcher + scheduler + speculative decoder) is generic
//! over [`InferenceBackend`]: anything that can run a batched prefill or
//! decode step against a KV cache can serve requests.  Two backends ship:
//!
//! * [`native`] — a pure-Rust CPU transformer forward built on the QUIK
//!   quantization substrate in [`crate::quant`] (INT4 nibble-packed weights,
//!   per-token asymmetric activation quantization, Eq.-1 dequantization,
//!   FP32 outlier columns).  No external dependencies; always available.
//! * [`pjrt`] — the PJRT/XLA artifact runtime (`--features pjrt`), which
//!   replays AOT-lowered JAX programs exported by `python/compile/aot.py`.
//!
//! The trait surface is deliberately small and shape-oriented: backends may
//! have *static* program shapes (PJRT artifacts are compiled for a fixed
//! `[batch, seq]`) or *dynamic* shapes (the native forward accepts any), so
//! callers negotiate the step length through [`InferenceBackend::step_seq`]
//! and pad to whatever the backend answers.

pub mod native;
#[cfg(feature = "pjrt")]
pub mod pjrt;

use anyhow::Result;

use crate::util::argmax;

/// Which weight format to serve.  `Fp16` is the full-precision reference
/// family (served as FP32 by the native CPU backend, FP16-named artifacts
/// by PJRT); `Quik4` is the paper's hybrid INT4 + outlier scheme.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    Fp16,
    Quik4,
}

impl Variant {
    pub fn prefix(&self) -> &'static str {
        match self {
            Variant::Fp16 => "fp16",
            Variant::Quik4 => "quik4",
        }
    }

    pub fn parse(s: &str) -> Option<Variant> {
        match s {
            "fp16" | "fp32" | "full" => Some(Variant::Fp16),
            "quik4" => Some(Variant::Quik4),
            _ => None,
        }
    }
}

/// Execution phase of one forward step.  `Verify` is a multi-token cached
/// forward (speculative decoding scores a whole draft window in one call);
/// backends that do not specialize it may treat it exactly like `Prefill`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Phase {
    Prefill,
    Decode,
    Verify,
}

impl Phase {
    pub fn name(&self) -> &'static str {
        match self {
            Phase::Prefill => "prefill",
            Phase::Decode => "decode",
            Phase::Verify => "verify",
        }
    }
}

/// KV-cache handle threaded between consecutive forward steps.
///
/// The state callers may touch is the *logical* length: schedulers roll
/// it back after padded prefills and the speculative decoder rolls it
/// back after partially-accepted draft windows.  Backends must guarantee
/// that positions at or beyond `len()` are masked out of attention and
/// are overwritten by subsequent steps.
///
/// ### The paged cache discipline
///
/// Physical storage is a backend concern, and the native backend stores
/// it as a shared **page pool**: fixed-size pages of `page_tokens`
/// positions each, with a per-row page table mapping logical positions
/// to pool pages.  The trait exposes that capacity model without leaking
/// the layout.  The contract is **incremental**: capacity is claimed as
/// writes advance, not reserved for a worst case up front.
///
/// * [`KvCache::page_tokens`] answers `Some(tokens-per-page)` for paged
///   caches, `None` for backends with monolithic per-row buffers;
/// * [`KvCache::total_pages`] / [`KvCache::free_pages`] are the
///   occupancy gauge — admission control checks free-page headroom, the
///   metrics report a pool-utilization gauge;
/// * [`KvCache::ensure_row_capacity`] is the demand-paging primitive:
///   map just enough pages for `row` to hold `tokens` positions, or
///   report `false` without side effects so the caller can free
///   capacity first (preempt a resident, defer an admission).  Forward
///   passes call it implicitly — the native forward checks the whole
///   step's page deficit *before* writing anything;
/// * [`KvCache::try_reserve_row`] survives as the optional
///   *conservative* mode: map a row's whole context budget up front,
///   all or nothing, so an admitted stream can never run dry mid-decode
///   (at the cost of concurrency — budget pages a stop token never
///   spends stay reserved);
/// * [`KvCache::evict_row`] / [`KvCache::restore_row`] are the victim
///   path behind preemption: eviction copies a row's mapped pages into
///   a spill buffer and returns them to the free list; restoration
///   remaps and refills them **bit-exactly** — including rollback/
///   replay state, so a restored row is indistinguishable from one that
///   was never touched.  [`KvCache::pages_spilled`] /
///   [`KvCache::pages_restored`] count the traffic;
/// * [`KvCache::reset_row`] returns the row's pages to the free list —
///   retirement immediately releases capacity to the next admission;
/// * rolling the logical length *back* keeps pages mapped: replay after
///   rollback must read the previously written content.
///
/// ### The refcount / aliasing contract
///
/// Paged caches may additionally support **page aliasing** — the
/// substrate of prefix caching ([`crate::coordinator::prefix`]).  Pages
/// are then reference-counted: each row page-table entry holding a page
/// counts one reference, and an out-of-band holder (the prefix store)
/// adds one via [`KvCache::retain_page`].  The rules:
///
/// * a page returns to the free list only when its **last** reference
///   drops — `reset_row`/`evict_row` *release* rather than free, so a
///   retiring row never yanks a page a neighbor still reads;
/// * [`KvCache::adopt_pages`] aliases a page-aligned run of live pages
///   into an **empty** row as its immutable prefix: no data movement,
///   logical length set to the aliased depth, the next forward appends
///   after it into fresh pages.  Everything inside a page travels with
///   the alias — for INT8 pages the per-token quant parameters — so an
///   aliased read is bit-identical to reading the original row;
/// * shared pages are **immutable** while any other holder references
///   them: a rollback into the aliased depth must privatize
///   (copy-before-write) before replay can rewrite a position;
/// * [`KvCache::release_page`] drops a `retain_page` reference (store
///   eviction); [`KvCache::row_pages`] exposes a row's table so the
///   engine can offer a retiring row's prompt pages to the store.
///
/// Every aliasing hook has an inert default (`row_pages` empty,
/// `adopt_pages` refuses, retain/release no-ops), so unpaged caches and
/// paged caches without aliasing need nothing new — engines detect
/// support by `adopt_pages` answering `true`.
///
/// Every hook has an unpaged default, so a dense fallback cache (and
/// the PJRT artifact cache) implements nothing new: `page_tokens() ==
/// None`, the gauges read zero, `ensure_row_capacity` and
/// `try_reserve_row` always succeed (capacity was allocated at
/// construction), and `evict_row`/`restore_row` answer `false` — a
/// dense cache has no pages to spill, so engines never preempt on it.
/// A dense cache **must** keep positions `>= len` masked and
/// overwritable; it need **not** implement spill, reservation, or any
/// page accounting.
pub trait KvCache {
    /// Current logical context length (tokens resident in the cache).
    fn len(&self) -> usize;

    /// Roll the logical length backward (or forward over known-valid
    /// entries).  Positions `>= len` become writable garbage.  Rolling
    /// *past the cache capacity* is a caller bug; backends should refuse
    /// it loudly (the native cache debug-asserts) rather than clamp
    /// silently.
    fn set_len(&mut self, len: usize);

    /// Set one row's logical length.  After a right-padded mixed-length
    /// prefill, schedulers roll each row back to its *true* prompt
    /// length so the row decodes at its own positions and never attends
    /// pad KV — batched decode becomes bit-exact with solo decode.
    /// Backends without per-row cache lengths may ignore this call and
    /// keep the pad-KV approximation (the default implementation).
    fn set_row_len(&mut self, row: usize, len: usize) {
        let _ = (row, len);
    }

    /// Does this cache honor [`KvCache::set_row_len`]?  Schedulers use
    /// this to decide whether per-row decode budgets are sound: with
    /// per-row lengths a short row in a mixed-length batch can keep
    /// decoding after a longer row has exhausted *its* context (the
    /// finished row is frozen at its own length); without them every
    /// row shares one logical length, so budgets must stay clipped by
    /// the batch-max prompt.  Default `false` (the pad-KV approximation).
    fn per_row_lens(&self) -> bool {
        false
    }

    /// Recycle one row for a brand-new sequence without disturbing any
    /// neighbor row: the row becomes logically empty and every position
    /// of it is writable garbage.  The continuous batching engine
    /// ([`crate::coordinator::engine::ContinuousEngine`]) calls this when
    /// a slot retires, so the next admitted request starts from a clean
    /// row while resident rows keep decoding in place.  Paged caches
    /// additionally return the row's pages to the free pool here.  The
    /// default implementation is `set_row_len(row, 0)`, which is
    /// sufficient for any monolithic cache whose `>= len` positions are
    /// masked and overwritten.
    fn reset_row(&mut self, row: usize) {
        self.set_row_len(row, 0);
    }

    /// Tokens per physical cache page, or `None` for caches without a
    /// paged layout (monolithic per-row buffers).  When `Some`, the
    /// page-granular hooks below are live and admission control should
    /// check free-page headroom via [`KvCache::try_reserve_row`].
    fn page_tokens(&self) -> Option<usize> {
        None
    }

    /// Total pages in the pool (0 when unpaged).
    fn total_pages(&self) -> usize {
        0
    }

    /// Currently free pages in the pool (0 when unpaged).
    fn free_pages(&self) -> usize {
        0
    }

    /// Cumulative pages handed out from the free list (monotonic
    /// counter; 0 when unpaged).  With [`KvCache::pages_freed`] this
    /// gives the metrics pipeline churn counters alongside the
    /// `free_pages` gauge.
    fn pages_allocated(&self) -> u64 {
        0
    }

    /// Cumulative pages returned to the free list (monotonic counter;
    /// 0 when unpaged).
    fn pages_freed(&self) -> u64 {
        0
    }

    /// Reserve capacity for `row` to hold `tokens` total positions, all
    /// or nothing: on `true` the row's pages are mapped and later writes
    /// up to `tokens` cannot exhaust the pool; on `false` nothing
    /// changed and the caller should defer (backpressure) rather than
    /// admit.  This is the *conservative* admission mode
    /// ([`crate::config::OvercommitMode::Reserve`]); demand-paged
    /// serving uses [`KvCache::ensure_row_capacity`] instead.  Unpaged
    /// caches always succeed — their capacity was reserved at
    /// construction.
    fn try_reserve_row(&mut self, row: usize, tokens: usize) -> bool {
        let _ = (row, tokens);
        true
    }

    /// Map just enough pages for `row` to hold `tokens` total positions
    /// — the demand-paging primitive.  Idempotent over already-mapped
    /// pages: only the deficit beyond the row's current mapping is
    /// claimed.  On `false` nothing changed (the pool cannot supply the
    /// deficit) and the caller should free capacity — preempt a
    /// resident, defer an admission — before retrying.  Unpaged caches
    /// always succeed.
    fn ensure_row_capacity(&mut self, row: usize, tokens: usize) -> bool {
        let _ = (row, tokens);
        true
    }

    /// Spill one row: copy its mapped pages (data *and* any quantization
    /// metadata) into an internal spill buffer, return the pages to the
    /// free list, and remember the row's logical length.  Returns
    /// `false` — with no side effects — when the cache cannot spill
    /// (unpaged, or the row holds no pages).  The engine's preemption
    /// path; [`KvCache::restore_row`] is the exact inverse.
    fn evict_row(&mut self, row: usize) -> bool {
        let _ = row;
        false
    }

    /// Restore a previously evicted row **bit-exactly**: remap pages
    /// from the free list, refill them from the spill buffer, and
    /// reinstate the row's logical length — the row then replays as if
    /// never spilled (rollback semantics included).  Returns `false` —
    /// with no side effects — when no spill exists for `row` or the
    /// pool lacks the pages; the caller retries after retirements.
    fn restore_row(&mut self, row: usize) -> bool {
        let _ = row;
        false
    }

    /// The pool pages `row` currently maps, in page-table order (empty
    /// when unpaged or the cache does not expose aliasing).  The engine
    /// reads this at retirement to offer the row's prompt-prefix pages
    /// to the prefix store.
    fn row_pages(&self, row: usize) -> Vec<usize> {
        let _ = row;
        Vec::new()
    }

    /// Alias `pages` into the empty `row` as its immutable prefix (see
    /// the refcount/aliasing contract above): each page gains a
    /// reference, the row's logical length becomes
    /// `pages.len() × page_tokens`, and no data moves.  Returns `false`
    /// — with no side effects — when the row is not empty, the alias
    /// would exceed the context, or the cache does not support aliasing
    /// (the default).
    fn adopt_pages(&mut self, row: usize, pages: &[usize]) -> bool {
        let _ = (row, pages);
        false
    }

    /// Add one out-of-band reference to `page` (the prefix store pinning
    /// a retired row's prompt pages).  No-op when unsupported.
    fn retain_page(&mut self, page: usize) {
        let _ = page;
    }

    /// Drop an out-of-band reference to `page` (prefix-store eviction);
    /// the page returns to the free list once no row aliases it either.
    /// No-op when unsupported.
    fn release_page(&mut self, page: usize) {
        let _ = page;
    }

    /// Current reference count of `page` (1 = sole holder, so releasing
    /// the last out-of-band reference would return it to the free
    /// list).  Only meaningful for page ids obtained from
    /// [`KvCache::row_pages`]; caches without aliasing answer 1.
    fn page_refcount(&self, page: usize) -> u32 {
        let _ = page;
        1
    }

    /// Cumulative pages spilled by [`KvCache::evict_row`] (monotonic
    /// counter; 0 when unpaged or never preempted).
    fn pages_spilled(&self) -> u64 {
        0
    }

    /// Cumulative pages refilled by [`KvCache::restore_row`] (monotonic
    /// counter; 0 when unpaged or never preempted).
    fn pages_restored(&self) -> u64 {
        0
    }

    /// High-water mark of simultaneously mapped pages over the cache's
    /// lifetime (gauge; 0 when unpaged).  Tracked at map/restore time so
    /// it catches intra-step peaks the per-loop metrics sample would
    /// miss.
    fn pages_high_water(&self) -> usize {
        0
    }

    fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

/// Row-major `[batch, seq, vocab]` logits of one forward step.
#[derive(Debug, Clone)]
pub struct StepOutput {
    pub logits: Vec<f32>,
    pub batch: usize,
    pub seq: usize,
    pub vocab: usize,
}

impl StepOutput {
    /// Logits row at (batch, pos).
    pub fn row(&self, b: usize, pos: usize) -> &[f32] {
        let base = (b * self.seq + pos) * self.vocab;
        &self.logits[base..base + self.vocab]
    }

    /// Greedy token at (batch, pos).
    pub fn argmax_at(&self, b: usize, pos: usize) -> i32 {
        argmax(self.row(b, pos))
    }

    /// Argmax token per batch row at the *last* position (greedy decode).
    pub fn argmax_last(&self) -> Vec<i32> {
        (0..self.batch).map(|b| self.argmax_at(b, self.seq - 1)).collect()
    }
}

/// An execution engine the coordinator can serve requests through.
///
/// Lifecycle: `prepare` each (variant, phase, batch) you intend to run
/// (compile artifacts / quantize weights — idempotent), then `new_cache`
/// per sequence-batch and drive `forward` steps against it.  `prepare`
/// is the only method that mutates the backend, so schedulers can hold a
/// shared reference during steady-state serving.
pub trait InferenceBackend {
    type Cache: KvCache;

    /// Human-readable model/backend identifier (logs and reports).
    fn name(&self) -> &str;

    /// Vocabulary size of the served model.
    fn vocab(&self) -> usize;

    /// Maximum total context (prompt + generated) a cache can hold.
    fn max_context(&self) -> usize;

    /// Variant/program names this backend can serve (enumeration for the
    /// CLI and admission checks).
    fn variants(&self) -> Vec<String>;

    /// Make (variant, phase, batch) runnable: compile/load the program or
    /// quantize the weight stack.  Must be idempotent.
    fn prepare(&mut self, variant: Variant, phase: Phase, batch: usize) -> Result<()>;

    /// The per-call sequence length the prepared program consumes.
    /// Static-shape backends return their compiled length; dynamic-shape
    /// backends echo `requested` (clamped to the context budget).
    fn step_seq(
        &self,
        variant: Variant,
        phase: Phase,
        batch: usize,
        requested: usize,
    ) -> Result<usize>;

    /// Fresh zeroed KV cache for `batch` concurrent rows.
    fn new_cache(&self, variant: Variant, batch: usize) -> Result<Self::Cache>;

    /// One forward step.  `tokens` is `[batch, seq]` row-major with
    /// `seq = tokens.len() / batch`; the cache advances by `seq`.
    fn forward(
        &self,
        variant: Variant,
        phase: Phase,
        tokens: &[i32],
        batch: usize,
        cache: &mut Self::Cache,
    ) -> Result<StepOutput>;

    /// One forward step over a *subset* of the batch rows.  `active[b]`
    /// marks the rows this step computes; inactive rows are frozen:
    /// their KV entries are neither attended nor written, their logical
    /// cache length does not advance, and their logits rows are
    /// unspecified (callers must discard them).  Token values in
    /// inactive rows are arbitrary placeholders (pad tokens) — a
    /// masking backend may never read them at all.
    ///
    /// Masking backends are expected to **compact**: gather the active
    /// rows into a dense `1..=batch`-row activation batch before the
    /// linears (any compacted width must be valid under the `prepare`d
    /// shapes), so step compute scales with occupancy rather than slot
    /// count, and scatter logits back to slot positions bit-identically.
    ///
    /// This is the primitive behind the continuous batching engine
    /// ([`crate::coordinator::engine::ContinuousEngine`]): a newly
    /// admitted request prefills its slot while every resident row stays
    /// frozen mid-decode, and free slots cost nothing — no attention
    /// *and* no GEMM rows.
    ///
    /// The default implementation **ignores the mask** and runs a plain
    /// [`InferenceBackend::forward`] with every row live — only sound
    /// while [`InferenceBackend::supports_row_masking`] answers `false`,
    /// which keeps such backends on the static batch-at-a-time loop.
    #[allow(clippy::too_many_arguments)]
    fn forward_masked(
        &self,
        variant: Variant,
        phase: Phase,
        tokens: &[i32],
        batch: usize,
        cache: &mut Self::Cache,
        active: &[bool],
    ) -> Result<StepOutput> {
        let _ = active;
        self.forward(variant, phase, tokens, batch, cache)
    }

    /// Does [`InferenceBackend::forward_masked`] actually honor the row
    /// mask?  The continuous engine requires `true` here *and*
    /// [`KvCache::per_row_lens`] on the cache; backends answering
    /// `false` (the default) are served by the static fallback loop.
    fn supports_row_masking(&self) -> bool {
        false
    }

    /// Estimated incremental memory cost, in bytes, of serving **one
    /// additional concurrent slot** at full context (its KV-cache rows
    /// plus its share of activation buffers).  The continuous engine
    /// divides a memory budget by this to autoscale its slot count when
    /// no explicit `QUIK_SLOTS`/`--slots` setting is given.  `None` (the
    /// default) means the backend cannot estimate it; the engine then
    /// falls back to its workload floor.
    fn slot_bytes(&self) -> Option<u64> {
        None
    }

    /// Estimated resident cost, in bytes, of a **full prefix store** over
    /// this backend's paged KV pool (the store's page capacity at the
    /// configured page layout and precision).  The continuous engine
    /// charges this against the same memory budget slot autoscaling
    /// divides, so enabling the prefix cache trades slots for reuse
    /// explicitly instead of silently overcommitting memory.  `None`
    /// (the default) means unpaged or unsupported — nothing is charged.
    fn prefix_store_bytes(&self) -> Option<u64> {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn variant_names() {
        assert_eq!(Variant::Quik4.prefix(), "quik4");
        assert_eq!(Variant::parse("fp16"), Some(Variant::Fp16));
        assert_eq!(Variant::parse("fp32"), Some(Variant::Fp16));
        assert_eq!(Variant::parse("x"), None);
    }

    #[test]
    fn phase_names() {
        assert_eq!(Phase::Prefill.name(), "prefill");
        assert_eq!(Phase::Decode.name(), "decode");
        assert_eq!(Phase::Verify.name(), "verify");
    }

    #[test]
    fn step_output_rows() {
        let out = StepOutput {
            logits: vec![0.0, 1.0, /* row (0,1) */ 3.0, 2.0],
            batch: 1,
            seq: 2,
            vocab: 2,
        };
        assert_eq!(out.row(0, 1), &[3.0, 2.0]);
        assert_eq!(out.argmax_at(0, 0), 1);
        assert_eq!(out.argmax_last(), vec![0]);
    }
}
