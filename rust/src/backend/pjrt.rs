//! `PjrtBackend` — the PJRT/XLA artifact runtime behind [`InferenceBackend`].
//!
//! Wraps [`ModelRuntime`]: each (variant, phase, batch) maps to a manifest
//! artifact named `{variant}_{phase}_b{batch}` with a *static* compiled
//! shape, so [`InferenceBackend::step_seq`] answers the artifact's fixed
//! sequence length and callers pad to it.  Only compiled behind the
//! `pjrt` cargo feature (needs the vendored XLA bridge crate).

use anyhow::{bail, Context, Result};

use crate::backend::{InferenceBackend, KvCache, Phase, StepOutput, Variant};
use crate::runtime::engine::{ModelRuntime, RunningCache};

impl KvCache for RunningCache {
    fn len(&self) -> usize {
        self.cache_len.max(0) as usize
    }

    fn set_len(&mut self, len: usize) {
        self.cache_len = len as i32;
    }
}

/// PJRT artifact backend for one model of an artifact directory.
pub struct PjrtBackend {
    rt: ModelRuntime,
    vocab: usize,
    max_ctx: usize,
}

impl PjrtBackend {
    /// Load the manifest and create the PJRT CPU client.
    pub fn load(artifacts_dir: impl AsRef<std::path::Path>, model: &str) -> Result<Self> {
        let rt = ModelRuntime::load(artifacts_dir, model)?;
        let entry = rt.manifest.model(model)?;
        let vocab = entry.config.vocab;
        let max_ctx = entry.config.max_seq;
        Ok(Self { rt, vocab, max_ctx })
    }

    fn artifact_name(variant: Variant, phase: Phase, batch: usize) -> String {
        format!("{}_{}_b{}", variant.prefix(), phase.name(), batch)
    }

    pub fn runtime(&self) -> &ModelRuntime {
        &self.rt
    }

    pub fn runtime_mut(&mut self) -> &mut ModelRuntime {
        &mut self.rt
    }
}

impl InferenceBackend for PjrtBackend {
    type Cache = RunningCache;

    fn name(&self) -> &str {
        &self.rt.model_name
    }

    fn vocab(&self) -> usize {
        self.vocab
    }

    fn max_context(&self) -> usize {
        self.max_ctx
    }

    fn variants(&self) -> Vec<String> {
        self.rt.variants()
    }

    fn prepare(&mut self, variant: Variant, phase: Phase, batch: usize) -> Result<()> {
        let name = Self::artifact_name(variant, phase, batch);
        self.rt
            .ensure_loaded(&name)
            .with_context(|| format!("compiling artifact {name}"))
            .map(|_| ())
    }

    fn step_seq(
        &self,
        variant: Variant,
        phase: Phase,
        batch: usize,
        _requested: usize,
    ) -> Result<usize> {
        let name = Self::artifact_name(variant, phase, batch);
        let art = self
            .rt
            .artifact(&name)
            .with_context(|| format!("artifact {name} not prepared"))?;
        Ok(art.spec.seq)
    }

    fn new_cache(&self, variant: Variant, batch: usize) -> Result<RunningCache> {
        // Every phase of a (variant, batch) family shares one cache shape;
        // the prefill artifact defines it.
        let name = Self::artifact_name(variant, Phase::Prefill, batch);
        let art = self
            .rt
            .artifact(&name)
            .with_context(|| format!("artifact {name} not prepared"))?;
        art.new_cache()
    }

    fn forward(
        &self,
        variant: Variant,
        phase: Phase,
        tokens: &[i32],
        batch: usize,
        cache: &mut RunningCache,
    ) -> Result<StepOutput> {
        let name = Self::artifact_name(variant, phase, batch);
        let art = self
            .rt
            .artifact(&name)
            .with_context(|| format!("artifact {name} not prepared"))?;
        if batch != art.spec.batch {
            bail!("batch {batch} != artifact batch {}", art.spec.batch);
        }
        art.run(tokens, cache)
    }
}
