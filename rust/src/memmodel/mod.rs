//! Byte-exact memory accounting (Table 6, §4.2 "Peak Memory Usage").
//!
//! Counts every tensor a QUIK deployment holds: quantized base weights
//! (nibble-packed INT4 / INT8), FP16 outlier columns, per-channel scales
//! and `w_reduced` vectors, FP16 embeddings + LM head, and the inference
//! working set (hidden states, quantization buffers, attention workspace,
//! logits).  The FP16 baseline is the same model with 2-byte weights.
//!
//! Absolute numbers depend on allocator/framework slack the paper doesn't
//! itemize; the reproduced quantities are the *reduction ratios* (≈47% for
//! QUIK-8B, ≈74% for QUIK-4B on OPT-66B) and the GPU-count estimates of
//! Fig. 8.

use crate::config::{ModelSpec, QuikPolicy};
use crate::quant::sparse::sparse24_weight_bytes;

const GB: f64 = 1e9;

/// Memory report for one (model, policy) pair.
#[derive(Debug, Clone, Copy)]
pub struct MemoryReport {
    pub weight_bytes: f64,
    pub outlier_bytes: f64,   // FP16 outlier weight columns (Table 6 note)
    pub metadata_bytes: f64,  // scales, w_reduced, permutations
    pub embedding_bytes: f64, // embeddings + LM head (FP16 always)
    pub activation_bytes: f64,
    pub kv_cache_bytes: f64,
}

impl MemoryReport {
    pub fn total(&self) -> f64 {
        self.weight_bytes
            + self.outlier_bytes
            + self.metadata_bytes
            + self.embedding_bytes
            + self.activation_bytes
            + self.kv_cache_bytes
    }

    pub fn total_gb(&self) -> f64 {
        self.total() / GB
    }
}

/// How the serving stack stores resident K/V — the knobs that decide
/// `kv_cache_bytes`.  The paper's Table-6 numbers model an FP16, densely
/// allocated cache ([`KvCacheSpec::fp16_dense`]); the native backend
/// stores FP32 or INT8 *pages* ([`KvCacheSpec::paged`]), which charge
/// page-granular rounding, the page-table entries, and (for INT8) the
/// per-token quantization parameters.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvCacheSpec {
    /// Storage bits per K/V element (8, 16 or 32).
    pub bits: u32,
    /// Tokens per page; 0 = monolithic per-row buffers (no page rounding
    /// and no page-table overhead).
    pub page_tokens: usize,
}

impl KvCacheSpec {
    /// The paper's serving model: FP16 K/V, dense per-row allocation.
    pub fn fp16_dense() -> Self {
        Self { bits: 16, page_tokens: 0 }
    }

    /// A paged pool at `bits` precision (the native backend's layout).
    pub fn paged(bits: u32, page_tokens: usize) -> Self {
        Self { bits, page_tokens }
    }
}

/// KV-cache bytes for `batch` rows of `seq` resident tokens under `kv`:
/// K and V at `kv.bits` per element (GQA/MQA-aware width), plus — for
/// paged layouts — rounding up to whole pages, one 8-byte page-table
/// entry per mapped page, and — for INT8 pages — one f32 `(scale, zero)`
/// pair per cached `d_head` vector per tensor (the per-token asymmetric
/// quantization parameters).
pub fn kv_cache_bytes(spec: &ModelSpec, kv: &KvCacheSpec, batch: usize, seq: usize) -> f64 {
    let (positions, table_bytes) = if kv.page_tokens > 0 {
        let pages_per_row = seq.div_ceil(kv.page_tokens);
        (pages_per_row * kv.page_tokens, (batch * pages_per_row) as f64 * 8.0)
    } else {
        (seq, 0.0)
    };
    let elems = (spec.n_layers * batch * positions * spec.kv_dim()) as f64;
    let data = 2.0 * elems * (kv.bits as f64 / 8.0); // K and V planes
    let quant_meta = if kv.bits == 8 {
        // scale + zero f32, per (layer, row, kv_head, position), K and V
        (spec.n_layers * batch * positions * spec.n_kv_heads) as f64 * 16.0
    } else {
        0.0
    };
    data + quant_meta + table_bytes
}

/// Spill-buffer bytes for preempting one victim row holding `seq`
/// resident tokens under demand-paged overcommit: the row's mapped
/// pages are copied out verbatim — page data at `kv.bits` (whole pages,
/// same rounding as the pool) plus, for INT8 pages, the per-token
/// quantization parameters that make the restore bit-exact.  No
/// page-table entries are charged: the spill buffer stores contents,
/// not mappings (the pages themselves return to the free list — that is
/// the point of the eviction).  Monolithic layouts (`page_tokens == 0`)
/// have no victim path and spill nothing.
pub fn kv_spill_bytes(spec: &ModelSpec, kv: &KvCacheSpec, seq: usize) -> f64 {
    if kv.page_tokens == 0 {
        return 0.0;
    }
    let positions = seq.div_ceil(kv.page_tokens) * kv.page_tokens;
    let elems = (spec.n_layers * positions * spec.kv_dim()) as f64;
    let data = 2.0 * elems * (kv.bits as f64 / 8.0); // K and V planes
    let quant_meta = if kv.bits == 8 {
        (spec.n_layers * positions * spec.n_kv_heads) as f64 * 16.0
    } else {
        0.0
    };
    data + quant_meta
}

/// Resident bytes of a prefix store pinning `pages` pool pages: page
/// data at `kv.bits` for both K/V planes, the INT8 per-token quant
/// parameters (they travel inside the page — aliased KV8 reads are
/// bit-exact), and one 8-byte index entry per page (the radix node's
/// page pointer, the aliasing analog of a page-table entry).  The
/// continuous engine charges this against the same memory budget slot
/// autoscaling divides, so enabling the prefix cache visibly trades a
/// slot's worth of budget for reuse instead of overcommitting.
/// Monolithic layouts (`page_tokens == 0`) cannot alias and store
/// nothing.
pub fn kv_prefix_store_bytes(spec: &ModelSpec, kv: &KvCacheSpec, pages: usize) -> f64 {
    if kv.page_tokens == 0 || pages == 0 {
        return 0.0;
    }
    let positions = pages * kv.page_tokens;
    let elems = (spec.n_layers * positions * spec.kv_dim()) as f64;
    let data = 2.0 * elems * (kv.bits as f64 / 8.0); // K and V planes
    let quant_meta = if kv.bits == 8 {
        (spec.n_layers * positions * spec.n_kv_heads) as f64 * 16.0
    } else {
        0.0
    };
    data + quant_meta + pages as f64 * 8.0
}

/// Peak memory of a prefill pass (`batch` × `seq` tokens) under the
/// paper's serving model — FP16 dense K/V ([`KvCacheSpec::fp16_dense`]),
/// which is what Table 6 reports.  Backends sizing their *own* slots
/// must pass their actual cache layout to [`memory_report_with_kv`]
/// instead (the native backend stores FP32 or INT8 pages, not FP16).
pub fn memory_report(
    spec: &ModelSpec,
    policy: &QuikPolicy,
    batch: usize,
    seq: usize,
) -> MemoryReport {
    memory_report_with_kv(spec, policy, batch, seq, &KvCacheSpec::fp16_dense())
}

/// [`memory_report`] with an explicit KV-cache layout, so
/// `kv_cache_bytes` reflects the precision and page structure a backend
/// actually allocates.
pub fn memory_report_with_kv(
    spec: &ModelSpec,
    policy: &QuikPolicy,
    batch: usize,
    seq: usize,
    kv: &KvCacheSpec,
) -> MemoryReport {
    let policy = policy.specialize(spec.family);
    let mut weight_bytes = 0f64;
    let mut outlier_bytes = 0f64;
    let mut metadata_bytes = 0f64;

    for shape in spec.linear_shapes() {
        let plan = policy.plan_for(shape.name, shape.in_features);
        let n_out = plan.n_outlier.min(shape.in_features);
        let k_base = shape.in_features - n_out;
        let n = shape.out_features;
        let per_layer_weights = if plan.weight_bits >= 16 {
            (n * shape.in_features) as f64 * 2.0
        } else if plan.sparse24 {
            sparse24_weight_bytes(n, k_base, plan.weight_bits) as f64
        } else {
            (n * k_base) as f64 * plan.weight_bits as f64 / 8.0
        };
        weight_bytes += per_layer_weights * spec.n_layers as f64;
        if plan.weight_bits < 16 {
            outlier_bytes += (n * n_out) as f64 * 2.0 * spec.n_layers as f64;
            // scale f32 + w_reduced f32 per output, perm i32 per input
            metadata_bytes +=
                ((n * 8) as f64 + (shape.in_features * 4) as f64) * spec.n_layers as f64;
        }
    }

    let embedding_bytes = 2.0 * (spec.vocab * spec.d_model) as f64 * 2.0;

    // Working set of a prefill pass (double-buffered hidden states, the
    // widest MLP intermediate, quantization buffers, logits).
    let toks = (batch * seq) as f64;
    let hidden = toks * spec.d_model as f64 * 2.0;
    let mlp_int = toks * spec.d_ff as f64 * 2.0;
    let qbuf = toks * spec.d_model.max(spec.d_ff) as f64; // int8 container + meta
    let logits = toks * spec.vocab as f64 * 2.0;
    let attn_ws = if matches!(spec.family, crate::config::Family::Llama) {
        // FlashAttention: O(m·d) workspace
        toks * spec.d_model as f64 * 2.0
    } else {
        // naive attention materializes [h, m, m] scores per active layer
        (spec.n_heads as f64) * (seq as f64) * (seq as f64) * batch as f64 * 2.0
    };
    let activation_bytes = 2.0 * hidden + 2.0 * mlp_int + qbuf + logits + attn_ws;

    // KV cache for the prefilled context, at the configured storage
    // precision and page layout.
    let kv_bytes = kv_cache_bytes(spec, kv, batch, seq);

    MemoryReport {
        weight_bytes,
        outlier_bytes,
        metadata_bytes,
        embedding_bytes,
        activation_bytes,
        kv_cache_bytes: kv_bytes,
    }
}

/// FP16 baseline / QUIK-8B / QUIK-4B triple for one model (a Table 6 row).
pub fn table6_row(spec: &ModelSpec, batch: usize, seq: usize) -> [f64; 3] {
    [
        memory_report(spec, &QuikPolicy::FP16, batch, seq).total_gb(),
        memory_report(spec, &QuikPolicy::QUIK_8B, batch, seq).total_gb(),
        memory_report(spec, &QuikPolicy::QUIK_4B, batch, seq).total_gb(),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::spec;

    #[test]
    fn table6_opt66b_reduction_ratios() {
        // paper: QUIK-8B ≈ 47% reduction, QUIK-4B ≈ 74% (vs ideal 50/75)
        let s = spec("opt-66b").unwrap();
        let [fp16, q8, q4] = table6_row(&s, 1, 2048);
        let r8 = 1.0 - q8 / fp16;
        let r4 = 1.0 - q4 / fp16;
        assert!((r8 - 0.47).abs() < 0.05, "8-bit reduction {r8}");
        assert!((r4 - 0.74).abs() < 0.05, "4-bit reduction {r4}");
    }

    #[test]
    fn table6_llama70b_reductions_smaller() {
        // LLaMA2-70B reductions trail OPT's (8-bit down-proj + 3.5x outlier
        // budget): paper reports 32%/67% vs OPT's 47%/74%.  The absolute
        // 8-bit gap also includes HF allocator slack we don't model, so the
        // asserted shape is the ordering + the <50 GB headline.
        let l = spec("llama2-70b").unwrap();
        let o = spec("opt-66b").unwrap();
        let [l16, l8, l4] = table6_row(&l, 1, 2048);
        let [o16, o8, o4] = table6_row(&o, 1, 2048);
        let lr4 = 1.0 - l4 / l16;
        let or4 = 1.0 - o4 / o16;
        let _ = (l8, o8); // 8-bit gap in the paper is allocator slack, not structure
        assert!(lr4 < or4, "llama 4-bit reduction {lr4} !< opt {or4}");
        assert!((lr4 - 0.67).abs() < 0.06, "4-bit reduction {lr4}");
        // the paper's headline: QUIK-4B LLaMA2-70B fits in < 50 GB
        assert!(l4 < 52.0, "llama2-70b QUIK-4B peak {l4} GB");
    }

    #[test]
    fn outlier_bytes_match_paper_note() {
        // Table 6 note: outliers ≈ 2.71 GB (OPT-66B), ≈ 4.06 GB (LLaMA2-70B)
        let o66 = memory_report(&spec("opt-66b").unwrap(), &QuikPolicy::QUIK_4B, 1, 2048)
            .outlier_bytes
            / 1e9;
        let l70 = memory_report(&spec("llama2-70b").unwrap(), &QuikPolicy::QUIK_4B, 1, 2048)
            .outlier_bytes
            / 1e9;
        assert!((o66 - 2.71).abs() < 0.7, "opt-66b outliers {o66} GB");
        assert!((l70 - 4.06).abs() < 1.0, "llama2-70b outliers {l70} GB");
    }

    #[test]
    fn falcon180b_fp16_exceeds_8x3090_but_quik_fits() {
        let s = spec("falcon-180b").unwrap();
        let [fp16, _q8, q4] = table6_row(&s, 1, 2048);
        assert!(fp16 > 192.0, "falcon-180b FP16 {fp16} GB must exceed 8×24 GB");
        assert!(q4 < 192.0, "falcon-180b QUIK-4B {q4} GB must fit the server");
    }

    #[test]
    fn kv_bytes_per_precision() {
        // One precision per test arm, against hand-computed expectations
        // on llama2-70b (GQA: kv_dim = 8 heads × 128 = 1024).
        let s = spec("llama2-70b").unwrap();
        let (batch, seq) = (4usize, 2048usize);
        let elems = (s.n_layers * batch * seq * s.kv_dim()) as f64;
        // FP16 dense: 2 planes × 2 bytes, no page or quant overhead
        let fp16 = kv_cache_bytes(&s, &KvCacheSpec::fp16_dense(), batch, seq);
        assert_eq!(fp16, 2.0 * elems * 2.0);
        // FP32 paged, page divides seq: 2 planes × 4 bytes + table entries
        let f32p = kv_cache_bytes(&s, &KvCacheSpec::paged(32, 64), batch, seq);
        let table = (batch * (seq / 64)) as f64 * 8.0;
        assert_eq!(f32p, 2.0 * elems * 4.0 + table);
        // INT8 paged: 1 byte/elem + f32 scale+zero per d_head vector per
        // plane + table entries — well under half the FP32 layout
        let i8p = kv_cache_bytes(&s, &KvCacheSpec::paged(8, 64), batch, seq);
        let qmeta = (s.n_layers * batch * seq * s.n_kv_heads) as f64 * 16.0;
        assert_eq!(i8p, 2.0 * elems + qmeta + table);
        assert!(i8p < f32p / 2.0, "int8 pages {i8p} not under half of f32 {f32p}");
        // page-granular rounding: a partial page is charged whole
        let ragged = kv_cache_bytes(&s, &KvCacheSpec::paged(32, 64), 1, 65);
        let full = kv_cache_bytes(&s, &KvCacheSpec::paged(32, 64), 1, 128);
        assert_eq!(ragged, full, "65 tokens must charge 2 full 64-token pages");
    }

    #[test]
    fn spill_bytes_track_one_row_without_table_overhead() {
        let s = spec("llama2-70b").unwrap();
        // a one-row pool's data cost minus its page-table entries is
        // exactly what the spill buffer must hold
        let seq = 100usize; // ragged: charges 2 full 64-token pages
        let f32_pool_row = kv_cache_bytes(&s, &KvCacheSpec::paged(32, 64), 1, seq);
        let f32_table = seq.div_ceil(64) as f64 * 8.0;
        assert_eq!(kv_spill_bytes(&s, &KvCacheSpec::paged(32, 64), seq), f32_pool_row - f32_table);
        // INT8 spills carry the per-token quant params (restore must be
        // bit-exact), same table exclusion
        let i8_pool_row = kv_cache_bytes(&s, &KvCacheSpec::paged(8, 64), 1, seq);
        assert_eq!(kv_spill_bytes(&s, &KvCacheSpec::paged(8, 64), seq), i8_pool_row - f32_table);
        // monolithic caches have no victim path
        assert_eq!(kv_spill_bytes(&s, &KvCacheSpec::fp16_dense(), seq), 0.0);
    }

    #[test]
    fn prefix_store_bytes_match_one_row_of_pages() {
        let s = spec("llama2-70b").unwrap();
        // a store pinning exactly one row's worth of pages costs that
        // row's pool bytes (data + quant meta + one index entry per page)
        let seq = 128usize;
        let pages = seq / 64;
        for bits in [32u32, 8] {
            let kv = KvCacheSpec::paged(bits, 64);
            let row = kv_cache_bytes(&s, &kv, 1, seq);
            assert_eq!(
                kv_prefix_store_bytes(&s, &kv, pages),
                row,
                "bits={bits}: store pages must cost the same as pool pages"
            );
        }
        // monolithic layouts cannot alias; empty stores are free
        assert_eq!(kv_prefix_store_bytes(&s, &KvCacheSpec::fp16_dense(), 4), 0.0);
        assert_eq!(kv_prefix_store_bytes(&s, &KvCacheSpec::paged(32, 64), 0), 0.0);
    }

    #[test]
    fn memory_report_with_kv_changes_only_kv_term() {
        let s = spec("opt-66b").unwrap();
        let pol = QuikPolicy::QUIK_4B;
        let base = memory_report(&s, &pol, 1, 2048);
        let paged = memory_report_with_kv(&s, &pol, 1, 2048, &KvCacheSpec::paged(8, 64));
        assert_eq!(base.weight_bytes, paged.weight_bytes);
        assert_eq!(base.activation_bytes, paged.activation_bytes);
        assert!(paged.kv_cache_bytes < base.kv_cache_bytes);
        // the default report is the paper's FP16 dense serving model
        assert_eq!(
            base.kv_cache_bytes,
            kv_cache_bytes(&s, &KvCacheSpec::fp16_dense(), 1, 2048)
        );
    }

    #[test]
    fn sparse24_reduces_further() {
        let s = spec("falcon-180b").unwrap();
        let mut pol = QuikPolicy::QUIK_4B;
        let dense = memory_report(&s, &pol, 1, 2048).weight_bytes;
        pol.sparse24 = true;
        let sparse = memory_report(&s, &pol, 1, 2048).weight_bytes;
        assert!(sparse < dense * 0.7, "2:4 weights {sparse} vs dense {dense}");
    }
}
