"""Tests for the L2 model zoo: forwards, KV-cache path, calibration capture."""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import data, model
from compile.modeling import common, presets
from compile.quik import policy


def tiny_cfg(family="llama", **kw):
    base = dict(family=family, vocab=64, d_model=32, n_layers=2, n_heads=2,
                d_ff=48 if family == "llama" else 64, max_seq=64,
                n_seeded_outliers=2, outlier_gain=4.0)
    base.update(kw)
    return common.ModelConfig(**base)


@pytest.mark.parametrize("family", ["llama", "opt", "falcon"])
def test_forward_shapes(family):
    cfg = tiny_cfg(family)
    params = common.init_params(cfg, seed=0)
    tokens = jnp.asarray(np.arange(12, dtype=np.int32).reshape(2, 6) % cfg.vocab)
    logits, caches = common.forward(params, tokens, cfg)
    assert logits.shape == (2, 6, cfg.vocab)
    assert len(caches) == cfg.n_layers
    k, v = caches[0]
    assert k.shape == (2, cfg.n_heads, 6, cfg.d_head)


@pytest.mark.parametrize("family", ["llama", "opt", "falcon"])
def test_causality(family):
    """Changing a future token must not affect past logits."""
    cfg = tiny_cfg(family)
    params = common.init_params(cfg, seed=1)
    r = np.random.default_rng(0)
    t1 = r.integers(0, cfg.vocab, size=(1, 8)).astype(np.int32)
    t2 = t1.copy()
    t2[0, -1] = (t2[0, -1] + 1) % cfg.vocab
    l1, _ = common.forward(params, jnp.asarray(t1), cfg)
    l2, _ = common.forward(params, jnp.asarray(t2), cfg)
    np.testing.assert_allclose(np.asarray(l1[0, :-1]), np.asarray(l2[0, :-1]), atol=1e-5)
    assert not np.allclose(np.asarray(l1[0, -1]), np.asarray(l2[0, -1]))


@pytest.mark.parametrize("family", ["llama", "opt", "falcon"])
def test_incremental_decode_matches_full_forward(family):
    """Concat-cache decode ≡ one-shot full forward."""
    cfg = tiny_cfg(family)
    params = common.init_params(cfg, seed=2)
    r = np.random.default_rng(1)
    toks = r.integers(0, cfg.vocab, size=(1, 10)).astype(np.int32)
    full, _ = common.forward(params, jnp.asarray(toks), cfg)

    pre, caches = common.forward(params, jnp.asarray(toks[:, :6]), cfg)
    outs = [np.asarray(pre)]
    for i in range(6, 10):
        step, caches = common.forward(
            params, jnp.asarray(toks[:, i : i + 1]), cfg,
            kv_caches=caches, position_offset=i,
        )
        outs.append(np.asarray(step))
    inc = np.concatenate(outs, axis=1)
    np.testing.assert_allclose(inc, np.asarray(full), rtol=2e-4, atol=2e-4)


@pytest.mark.parametrize("family", ["llama", "opt", "falcon"])
def test_fixed_buffer_cache_matches_full_forward(family):
    """forward_with_cache (the AOT/serving path) ≡ plain forward."""
    cfg = tiny_cfg(family)
    params = common.init_params(cfg, seed=3)
    r = np.random.default_rng(2)
    b, s_pre, n_dec, t_max = 2, 6, 3, 16
    toks = r.integers(0, cfg.vocab, size=(b, s_pre + n_dec)).astype(np.int32)
    full, _ = common.forward(params, jnp.asarray(toks), cfg)

    ck = jnp.zeros((cfg.n_layers, b, cfg.n_heads, t_max, cfg.d_head), jnp.float32)
    cv = jnp.zeros_like(ck)
    logits, ck, cv = common.forward_with_cache(
        params, jnp.asarray(toks[:, :s_pre]), cfg, ck, cv, jnp.int32(0)
    )
    np.testing.assert_allclose(
        np.asarray(logits), np.asarray(full[:, :s_pre]), rtol=2e-4, atol=2e-4
    )
    for i in range(n_dec):
        pos = s_pre + i
        logits, ck, cv = common.forward_with_cache(
            params, jnp.asarray(toks[:, pos : pos + 1]), cfg, ck, cv, jnp.int32(pos)
        )
        np.testing.assert_allclose(
            np.asarray(logits[:, 0]), np.asarray(full[:, pos]), rtol=2e-4, atol=2e-4
        )


def test_rope_relative_property():
    """RoPE: score(q_i, k_j) depends only on i - j (same content)."""
    dh = 8
    x = jnp.asarray(np.random.default_rng(3).normal(size=(1, 1, 1, dh)).astype(np.float32))
    q = jnp.tile(x, (1, 1, 6, 1))
    pos = jnp.arange(6)
    rq = common.rope(q, pos)
    s01 = float(jnp.dot(rq[0, 0, 0], rq[0, 0, 1]))
    s34 = float(jnp.dot(rq[0, 0, 3], rq[0, 0, 4]))
    assert abs(s01 - s34) < 1e-4


def test_capture_apply_collects_all_linears():
    cfg = tiny_cfg("llama")
    params = common.init_params(cfg, seed=4)
    store = {}
    tokens = jnp.asarray(np.zeros((1, 4), np.int32))
    common.forward(params, tokens, cfg, apply_linear=common.make_capture_apply(store))
    expected = {
        f"layers.{li}.{sec}.{nm}"
        for li in range(cfg.n_layers)
        for sec, nm in [
            ("self_attn", "q_proj"), ("self_attn", "k_proj"),
            ("self_attn", "v_proj"), ("self_attn", "o_proj"),
            ("mlp", "gate_proj"), ("mlp", "up_proj"), ("mlp", "down_proj"),
        ]
    }
    assert set(store) == expected
    x = store["layers.0.mlp.down_proj"][0]
    assert x.shape == (4, cfg.d_ff)


def test_num_params_matches_actual():
    for family in ("llama", "opt", "falcon"):
        cfg = tiny_cfg(family)
        params = common.init_params(cfg, seed=0)
        actual = sum(x.size for x in jax.tree.leaves(params))
        assert actual == cfg.num_params(), family


def test_seeded_outlier_channels_visible_in_activations():
    """Norm-gain seeding must create outlier features at the linear inputs."""
    cfg = tiny_cfg("llama", n_seeded_outliers=3, outlier_gain=10.0)
    params = common.init_params(cfg, seed=5)
    store = {}
    tokens = jnp.asarray(np.random.default_rng(4).integers(0, 64, (2, 16)).astype(np.int32))
    common.forward(params, tokens, cfg, apply_linear=common.make_capture_apply(store))
    x = store["layers.0.self_attn.q_proj"][0]
    linf = np.max(np.abs(x), axis=0)
    top3 = np.sort(linf)[-3:]
    rest = np.sort(linf)[:-3]
    assert top3.min() > 3 * np.median(rest)


# ---------------------------------------------------------------------------
# model-level quantization drivers
# ---------------------------------------------------------------------------


def quantize_setup(family="llama", scheme="quik", **pol_kw):
    cfg = tiny_cfg(family)
    params = common.init_params(cfg, seed=6)
    calib = data.calibration_sequences("pile", 8, 32, seed=0)[:, :-1]
    ci = model.calibrate(params, cfg, calib, max_rows=256)
    pol = policy.QuikPolicy(n_outlier=4, **pol_kw)
    qm = model.quantize_model(params, cfg, ci, pol, scheme=scheme)
    return cfg, params, qm


def test_quantize_model_covers_all_linears():
    cfg, params, qm = quantize_setup()
    assert len(qm.qlayers) == cfg.n_layers * len(cfg.linear_names())


def test_quantize_model_down_proj_is_8bit():
    _, _, qm = quantize_setup()
    dp = qm.qlayers["layers.0.mlp.down_proj"]
    qp = qm.qlayers["layers.0.self_attn.q_proj"]
    assert dp.plan.weight_bits == 8 and qp.plan.weight_bits == 4


def test_quantized_forward_close_to_fp():
    cfg, params, qm = quantize_setup()
    toks = jnp.asarray(np.random.default_rng(5).integers(0, 64, (2, 16)).astype(np.int32))
    lq, _ = qm.forward(toks)
    lf, _ = common.forward(params, toks, cfg)
    # 4-bit quantized logits track FP to a loose but meaningful tolerance
    rel = np.linalg.norm(np.asarray(lq - lf)) / np.linalg.norm(np.asarray(lf))
    assert rel < 0.35, rel


def test_quantized_forward_kernel_path_matches_ref_path():
    cfg, params, qm = quantize_setup()
    toks = jnp.asarray(np.random.default_rng(6).integers(0, 64, (1, 8)).astype(np.int32))
    l_ref, _ = qm.forward(toks, use_kernels=False)
    l_ker, _ = qm.forward(toks, use_kernels=True)
    np.testing.assert_allclose(
        np.asarray(l_ker), np.asarray(l_ref), rtol=5e-3, atol=5e-3
    )


def test_zero_outlier_count_reporting():
    _, _, qm = quantize_setup()
    assert qm.zero_outlier_layer_count() == 0
    # force zero outliers via policy
    cfg = tiny_cfg()
    params = common.init_params(cfg, seed=6)
    calib = data.calibration_sequences("pile", 4, 32, seed=0)[:, :-1]
    ci = model.calibrate(params, cfg, calib, max_rows=128)
    qmz = model.quantize_model(params, cfg, ci, policy.QuikPolicy(n_outlier=0), scheme="quik")
    assert qmz.zero_outlier_layer_count() == len(qmz.qlayers)


def test_presets_paper_scale_shapes():
    shapes = presets.paper_linear_shapes("llama2-70b")
    names = [n for n, _, _ in shapes]
    assert names == ["q_proj", "k_proj", "v_proj", "o_proj",
                     "gate_proj", "up_proj", "down_proj"]
    d = dict((n, (o, i)) for n, o, i in shapes)
    assert d["down_proj"] == (8192, 28672)
    assert presets.PAPER_SCALE["falcon-180b"]["d_model"] == 14848


def test_tiny_outlier_budget_rule():
    cfg = presets.TINY["llama-m"]
    assert presets.tiny_outliers(cfg) == 16  # 128 / 8
