"""Pallas kernel vs pure-jnp oracle — the core L1 correctness signal.

Every kernel in ``compile.kernels`` is checked against ``kernels.ref`` on
fixed shapes and under a hypothesis sweep over shapes, scale regimes and
bit widths.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import matmul, quant, quik_linear, ref

jax.config.update("jax_enable_x64", False)


def rng(seed):
    return np.random.default_rng(seed)


def rand_acts(r, m, k, scale=1.0):
    x = r.normal(size=(m, k)).astype(np.float32) * scale
    return jnp.asarray(x)


# ---------------------------------------------------------------------------
# quantize_acts (fused Pallas) vs ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("m,k", [(8, 16), (64, 128), (100, 96), (1, 32)])
def test_quantize_acts_matches_ref(bits, m, k):
    x = rand_acts(rng(0), m, k)
    got = quant.quantize_acts(x, bits, block_m=32)
    want = ref.quantize_acts_ref(x, bits)
    np.testing.assert_array_equal(np.asarray(got.q), np.asarray(want.q))
    np.testing.assert_allclose(got.scale, want.scale, rtol=1e-6)
    np.testing.assert_allclose(got.zero, want.zero, rtol=1e-6)


@pytest.mark.parametrize("bits", [4, 8])
def test_quantize_roundtrip_error_bounded(bits):
    """Reconstruction error per element is bounded by scale/2 (+ rounding)."""
    x = rand_acts(rng(1), 32, 64, scale=3.0)
    qa = quant.quantize_acts(x, bits, block_m=16)
    recon = ref.dequantize_acts_ref(qa, bits)
    err = np.abs(np.asarray(recon - x))
    bound = np.asarray(qa.scale)[:, None] * 0.5 + 1e-5
    assert (err <= bound).all()


def test_quantize_constant_row_no_nan():
    """A constant token row must not produce NaN (scale floor)."""
    x = jnp.ones((4, 32), jnp.float32) * 2.5
    qa = quant.quantize_acts(x, 4, block_m=4)
    assert np.isfinite(np.asarray(qa.scale)).all()
    assert np.isfinite(np.asarray(ref.dequantize_acts_ref(qa, 4))).all()


def test_quantize_signed_range():
    x = rand_acts(rng(2), 16, 48, scale=10.0)
    for bits in (4, 8):
        qa = quant.quantize_acts(x, bits, block_m=8)
        qmin, qmax = ref.act_qrange(bits)
        q = np.asarray(qa.q)
        assert q.min() >= qmin and q.max() <= qmax


# ---------------------------------------------------------------------------
# split_quantize (fused split) vs v1 (unfused) vs ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("n_outlier", [0, 8, 32])
@pytest.mark.parametrize("bits", [4, 8])
def test_split_quantize_matches_v1(n_outlier, bits):
    x = rand_acts(rng(3), 48, 96)
    qa2, fp2 = quant.split_quantize(x, n_outlier, bits, block_m=16)
    qa1, fp1 = quant.split_quantize_v1(x, n_outlier, bits)
    np.testing.assert_array_equal(np.asarray(qa2.q), np.asarray(qa1.q))
    np.testing.assert_allclose(qa2.scale, qa1.scale, rtol=1e-6)
    np.testing.assert_allclose(qa2.zero, qa1.zero, rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(fp2), np.asarray(fp1))


def test_split_quantize_outliers_exact_copy():
    """Outlier columns must be moved bit-exactly, never quantized."""
    x = rand_acts(rng(4), 32, 64, scale=100.0)
    _, fp = quant.split_quantize(x, 16, 4, block_m=8)
    np.testing.assert_array_equal(np.asarray(fp), np.asarray(x[:, 48:]))


def test_split_quantize_metadata_excludes_outliers():
    """Per-token scale/zero must be computed over the base block only."""
    r = rng(5)
    base = rand_acts(r, 16, 32)
    outl = rand_acts(r, 16, 8, scale=1000.0)  # huge outliers
    x = jnp.concatenate([base, outl], axis=1)
    qa, _ = quant.split_quantize(x, 8, 4, block_m=8)
    want = ref.quantize_acts_ref(base, 4)
    np.testing.assert_allclose(qa.scale, want.scale, rtol=1e-6)
    np.testing.assert_allclose(qa.zero, want.zero, rtol=1e-6)


# ---------------------------------------------------------------------------
# int_matmul vs ref (exact integer arithmetic)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "m,n,k", [(8, 8, 16), (64, 48, 96), (100, 33, 70), (1, 1, 8), (128, 128, 256)]
)
def test_int_matmul_exact(m, n, k):
    r = rng(6)
    qx = jnp.asarray(r.integers(-8, 8, size=(m, k)), jnp.int8)
    qw = jnp.asarray(r.integers(-7, 8, size=(n, k)), jnp.int8)
    got = matmul.int_matmul(qx, qw, block_m=32, block_n=32, block_k=32)
    want = ref.int_matmul_ref(qx, qw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


def test_int_matmul_int8_range_no_overflow():
    """Full-range int8 operands stay exact within int32 accumulation."""
    r = rng(7)
    k = 512
    qx = jnp.asarray(r.integers(-128, 128, size=(16, k)), jnp.int8)
    qw = jnp.asarray(r.integers(-127, 128, size=(16, k)), jnp.int8)
    got = matmul.int_matmul(qx, qw, block_m=16, block_n=16, block_k=128)
    want = ref.int_matmul_ref(qx, qw)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(want))


# ---------------------------------------------------------------------------
# dequantize (standalone + fused epilogue) vs ref
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("bits", [4, 8])
def test_dequantize_acc_matches_ref(bits):
    r = rng(8)
    m, n = 40, 56
    acc = jnp.asarray(r.integers(-10000, 10000, size=(m, n)), jnp.int32)
    sa = jnp.asarray(r.uniform(0.01, 1.0, m), jnp.float32)
    za = jnp.asarray(r.normal(size=m), jnp.float32)
    sw = jnp.asarray(r.uniform(0.01, 1.0, n), jnp.float32)
    wr = jnp.asarray(r.normal(size=n), jnp.float32)
    got = matmul.dequantize_acc(acc, sa, za, sw, wr, bits, block_m=16, block_n=16)
    want = ref.dequantize_ref(acc, sa, za, sw, wr, bits)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5)


@pytest.mark.parametrize("bits", [4, 8])
def test_fused_matmul_dequant_matches_unfused(bits):
    r = rng(9)
    m, n, k = 48, 40, 96
    qmax = 2 ** (bits - 1)
    qx = jnp.asarray(r.integers(-qmax, qmax, size=(m, k)), jnp.int8)
    qw = jnp.asarray(r.integers(-(qmax - 1), qmax, size=(n, k)), jnp.int8)
    sa = jnp.asarray(r.uniform(0.01, 1.0, m), jnp.float32)
    za = jnp.asarray(r.normal(size=m), jnp.float32)
    sw = jnp.asarray(r.uniform(0.01, 1.0, n), jnp.float32)
    wr = jnp.asarray(r.normal(size=n), jnp.float32)
    fp = jnp.asarray(r.normal(size=(m, n)), jnp.float32)
    fused = matmul.int_matmul_dequant(
        qx, qw, sa, za, sw, wr, fp, bits, block_m=16, block_n=16, block_k=32
    )
    acc = matmul.int_matmul(qx, qw, block_m=16, block_n=16, block_k=32)
    unfused = matmul.dequantize_acc(acc, sa, za, sw, wr, bits) + fp
    np.testing.assert_allclose(np.asarray(fused), np.asarray(unfused), rtol=1e-5)


# ---------------------------------------------------------------------------
# quik_linear end-to-end vs ref, all fusion versions
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("version", [1, 2, 3])
@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("n_outlier", [0, 16])
def test_quik_linear_matches_ref(version, bits, n_outlier):
    r = rng(10)
    m, n, k = 33, 48, 80
    x = rand_acts(r, m, k)
    w = jnp.asarray(r.normal(size=(n, k)).astype(np.float32))
    qw = ref.quantize_weights_ref(w, bits, n_outlier)
    bias = jnp.asarray(r.normal(size=n).astype(np.float32))
    got = quik_linear.quik_linear(x, qw, bias, version=version, block_m=16)
    want = ref.quik_linear_ref(x, qw, bias)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_quik_linear_versions_identical():
    """All three fusion versions must agree to float tolerance."""
    r = rng(11)
    x = rand_acts(r, 40, 64)
    w = jnp.asarray(r.normal(size=(32, 64)).astype(np.float32))
    qw = ref.quantize_weights_ref(w, 4, 8)
    ys = [
        np.asarray(quik_linear.quik_linear(x, qw, version=v, block_m=8))
        for v in (1, 2, 3)
    ]
    np.testing.assert_allclose(ys[0], ys[1], rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(ys[1], ys[2], rtol=1e-5, atol=1e-5)


def test_quik_linear_8bit_more_accurate_than_4bit():
    """INT8 path must reconstruct the FP product better than INT4."""
    r = rng(12)
    x = rand_acts(r, 64, 128)
    w = jnp.asarray(r.normal(size=(96, 128)).astype(np.float32))
    exact = np.asarray(x @ w.T)
    errs = {}
    for bits in (4, 8):
        qw = ref.quantize_weights_ref(w, bits, 0)
        y = np.asarray(quik_linear.quik_linear(x, qw, version=3, block_m=16))
        errs[bits] = np.mean((y - exact) ** 2)
    assert errs[8] < errs[4] / 4


def test_quik_linear_outliers_reduce_error():
    """With planted outlier features, keeping them FP must cut the error."""
    r = rng(13)
    m, n, k, n_out = 64, 48, 128, 16
    x = np.array(rand_acts(r, m, k))
    x[:, -n_out:] *= 50.0  # planted outlier features, already permuted last
    x = jnp.asarray(x)
    w = jnp.asarray(r.normal(size=(n, k)).astype(np.float32))
    exact = np.asarray(x @ w.T)
    qw0 = ref.quantize_weights_ref(w, 4, 0)
    qw1 = ref.quantize_weights_ref(w, 4, n_out)
    e0 = np.mean((np.asarray(quik_linear.quik_linear(x, qw0, version=3, block_m=16)) - exact) ** 2)
    e1 = np.mean((np.asarray(quik_linear.quik_linear(x, qw1, version=3, block_m=16)) - exact) ** 2)
    assert e1 < e0 / 10


# ---------------------------------------------------------------------------
# hypothesis sweeps: shapes × bits × scale regimes
# ---------------------------------------------------------------------------


@settings(max_examples=25, deadline=None)
@given(
    m=st.integers(1, 96),
    k=st.integers(2, 160),
    bits=st.sampled_from([4, 8]),
    scale=st.sampled_from([1e-3, 1.0, 1e3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_quantize_acts(m, k, bits, scale, seed):
    x = rand_acts(rng(seed), m, k, scale)
    got = quant.quantize_acts(x, bits, block_m=32)
    want = ref.quantize_acts_ref(x, bits)
    # XLA may fuse the divide differently inside the Pallas kernel than in
    # the jnp oracle; values landing exactly on a rounding tie can flip by
    # one level.  Allow off-by-one on a vanishing fraction of elements.
    diff = np.abs(np.asarray(got.q, np.int32) - np.asarray(want.q, np.int32))
    assert diff.max() <= 1
    assert (diff > 0).mean() <= 1e-3, f"{(diff > 0).mean():%} elements off"
    np.testing.assert_allclose(got.scale, want.scale, rtol=1e-6)


@settings(max_examples=20, deadline=None)
@given(
    m=st.integers(1, 64),
    n=st.integers(1, 64),
    k=st.integers(1, 128),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_int_matmul(m, n, k, seed):
    r = rng(seed)
    qx = jnp.asarray(r.integers(-8, 8, size=(m, k)), jnp.int8)
    qw = jnp.asarray(r.integers(-7, 8, size=(n, k)), jnp.int8)
    got = matmul.int_matmul(qx, qw, block_m=32, block_n=32, block_k=32)
    np.testing.assert_array_equal(np.asarray(got), np.asarray(ref.int_matmul_ref(qx, qw)))


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 48),
    n=st.integers(1, 48),
    k=st.integers(8, 96),
    bits=st.sampled_from([4, 8]),
    n_outlier_frac=st.floats(0.0, 0.4),
    version=st.sampled_from([1, 2, 3]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_quik_linear(m, n, k, bits, n_outlier_frac, version, seed):
    r = rng(seed)
    n_outlier = int(k * n_outlier_frac)
    if k - n_outlier < 2:
        n_outlier = 0
    x = rand_acts(r, m, k)
    w = jnp.asarray(r.normal(size=(n, k)).astype(np.float32))
    qw = ref.quantize_weights_ref(w, bits, n_outlier)
    got = quik_linear.quik_linear(x, qw, version=version, block_m=16)
    want = ref.quik_linear_ref(x, qw)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-4, atol=3e-4)
