"""Tests for the synthetic corpus, trainer plumbing and eval harness."""

import numpy as np
import jax.numpy as jnp
import pytest

from compile import data, evals, train
from compile.modeling import common


def tiny_cfg(**kw):
    # vocab must cover the synthetic corpus (data.VOCAB_SIZE tokens)
    base = dict(family="llama", vocab=data.VOCAB_SIZE, d_model=32, n_layers=2,
                n_heads=2, d_ff=48, max_seq=64, n_seeded_outliers=2,
                outlier_gain=4.0)
    base.update(kw)
    return common.ModelConfig(**base)


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_corpus_deterministic_and_in_vocab():
    a = data.make_corpus("train", 5000, seed=3)
    b = data.make_corpus("train", 5000, seed=3)
    np.testing.assert_array_equal(a, b)
    assert a.min() >= 0 and a.max() < data.VOCAB_SIZE


def test_corpus_splits_differ():
    a = data.make_corpus("train", 5000, seed=0)
    b = data.make_corpus("wikitext2", 5000, seed=0)
    assert not np.array_equal(a, b)


def test_corpus_zipfian_head():
    """A few tokens should dominate (natural-text-like marginals)."""
    c = data.make_corpus("train", 50_000, seed=1)
    counts = np.bincount(c, minlength=data.VOCAB_SIZE)
    top10 = np.sort(counts)[-10:].sum() / counts.sum()
    assert top10 > 0.2, f"top-10 token mass {top10}"


def test_corpus_has_structure():
    """Bigram entropy must be well below unigram entropy (learnable)."""
    c = data.make_corpus("train", 100_000, seed=2)
    uni = np.bincount(c, minlength=256).astype(float)
    uni /= uni.sum()
    h_uni = -(uni[uni > 0] * np.log(uni[uni > 0])).sum()
    # conditional entropy via bigram counts
    big = np.zeros((256, 256))
    np.add.at(big, (c[:-1], c[1:]), 1)
    rows = big.sum(1, keepdims=True)
    cond = big / np.maximum(rows, 1)
    h_cond = -(big * np.log(np.maximum(cond, 1e-12))).sum() / big.sum()
    assert h_cond < h_uni - 0.3, f"H(x)={h_uni:.2f} H(x|prev)={h_cond:.2f}"


def test_eval_windows_non_overlapping():
    toks = np.arange(1000, dtype=np.int32) % 256
    w = data.eval_windows(toks, 64)
    assert w.shape == ((1000 - 1) // 64, 65)
    np.testing.assert_array_equal(w[0], toks[:65])
    np.testing.assert_array_equal(w[1], toks[64:129])


def test_batches_shapes_and_bounds():
    toks = data.make_corpus("c4", 5000, seed=0)
    b = data.batches(toks, 8, 32, seed=1)
    assert b.shape == (8, 33)
    assert b.max() < data.VOCAB_SIZE


def test_calibration_sequences_shape():
    c = data.calibration_sequences("pile", 4, 16, seed=0)
    assert c.shape == (4, 17)


def test_unknown_split_raises():
    with pytest.raises(KeyError):
        data.make_corpus("imagenet", 10)


# ---------------------------------------------------------------------------
# trainer
# ---------------------------------------------------------------------------


def test_training_reduces_loss():
    cfg = tiny_cfg()
    params, losses = train.train(cfg, steps=25, batch=8, seq=32,
                                 corpus_tokens=20_000, log_every=0,
                                 name="pytest-tiny")
    assert losses[-1] < losses[0] * 0.9, f"{losses[0]} -> {losses[-1]}"


def test_checkpoint_cache_roundtrip():
    cfg = tiny_cfg(d_model=16, d_ff=24, n_heads=2)
    p1, l1 = train.train(cfg, steps=5, batch=4, seq=16, corpus_tokens=5_000,
                         log_every=0, name="pytest-cache")
    p2, l2 = train.train(cfg, steps=5, batch=4, seq=16, corpus_tokens=5_000,
                         log_every=0, name="pytest-cache")
    assert l1 == l2  # second call loaded the checkpoint
    import jax
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_adamw_moves_parameters():
    cfg = tiny_cfg(d_model=16, d_ff=24)
    params = common.init_params(cfg, seed=0)
    opt = train.adamw_init(params)
    batch = jnp.asarray(data.batches(data.make_corpus("train", 2000, 0), 4, 16, 0))
    p2, _, loss = train.train_step(params, opt, batch, 1e-3, cfg)
    assert float(loss) > 0
    moved = np.abs(np.asarray(p2["embed"]) - np.asarray(params["embed"])).max()
    assert moved > 0


# ---------------------------------------------------------------------------
# evals
# ---------------------------------------------------------------------------


def test_perplexity_sane_range():
    cfg = tiny_cfg()
    params, _ = train.train(cfg, steps=25, batch=8, seq=32,
                            corpus_tokens=20_000, log_every=0,
                            name="pytest-tiny")
    from compile import model as model_mod
    fwd = model_mod.make_forward(None, params, cfg)
    ppl = evals.perplexity(fwd, n_tokens=2048, seq=32)
    # trained: better than uniform (256); worse than perfect (1)
    assert 1.0 < ppl < 200.0, ppl


def test_perplexity_untrained_is_near_uniform():
    cfg = tiny_cfg(n_seeded_outliers=0)
    params = common.init_params(cfg, seed=1)
    from compile import model as model_mod
    fwd = model_mod.make_forward(None, params, cfg)
    ppl = evals.perplexity(fwd, n_tokens=1024, seq=32)
    assert ppl > 100.0, f"untrained model suspiciously good: {ppl}"


def test_zero_shot_chance_level_for_random_scorer():
    """A constant-logits model must score ~50% on every task."""
    class Uniform:
        def __call__(self, tokens):
            b, s = tokens.shape
            return jnp.zeros((b, s, data.VOCAB_SIZE)), None

    accs = evals.zero_shot_suite(Uniform(), n_items=32)
    for t, a in accs.items():
        if t == "avg":
            continue
        assert 0.2 <= a <= 0.8, f"{t}: {a}"


def test_zero_shot_trained_beats_chance_on_easy():
    cfg = tiny_cfg()
    params, _ = train.train(cfg, steps=25, batch=8, seq=32,
                            corpus_tokens=20_000, log_every=0,
                            name="pytest-tiny")
    from compile import model as model_mod
    fwd = model_mod.make_forward(None, params, cfg)
    acc = evals.zero_shot_accuracy(fwd, "piqa", n_items=32, prefix_len=24,
                                   cont_len=8)
    assert acc > 0.6, f"piqa-like accuracy {acc}"
