"""Tests for the offline QUIK calibration/quantization algorithms."""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels.ref import quik_linear_ref
from compile.quik import baselines, clipping, gptq, outliers, policy, quantize, sparsegpt


def rng(seed):
    return np.random.default_rng(seed)


def make_calib(r, tokens, k, outlier_idx=(), outlier_gain=50.0):
    """Calibration activations with planted outlier features."""
    x = r.normal(size=(tokens, k)).astype(np.float32)
    for i in outlier_idx:
        x[:, i] *= outlier_gain
    return x


# ---------------------------------------------------------------------------
# outlier selection & permutation
# ---------------------------------------------------------------------------


def test_select_outliers_finds_planted():
    x = make_calib(rng(0), 256, 64, outlier_idx=(3, 17, 40))
    stats = outliers.collect_stats(x)
    idx = outliers.select_outliers(stats, 3)
    assert set(idx.tolist()) == {3, 17, 40}


def test_outlier_permutation_moves_outliers_last():
    perm = outliers.outlier_permutation(8, np.array([1, 5]))
    assert perm.tolist() == [0, 2, 3, 4, 6, 7, 1, 5]
    inv = outliers.inverse_permutation(perm)
    assert (perm[inv] == np.arange(8)).all()
    assert (inv[perm] == np.arange(8)).all()


def test_permute_hessian_consistent():
    r = rng(1)
    x = make_calib(r, 128, 16)
    perm = outliers.outlier_permutation(16, np.array([2, 9]))
    h = gptq.hessian_from_calib(x)
    hp = outliers.permute_hessian(h, perm)
    hp_direct = gptq.hessian_from_calib(x[:, perm])
    np.testing.assert_allclose(hp, hp_direct, rtol=1e-6)


def test_merge_stats_linf_is_max():
    a = outliers.collect_stats(np.ones((4, 3), np.float32))
    b = outliers.collect_stats(np.full((4, 3), -5.0, np.float32))
    m = outliers.merge_stats([a, b])
    np.testing.assert_allclose(m.linf, [5, 5, 5])


def test_select_outliers_bounds():
    stats = outliers.collect_stats(np.ones((2, 4), np.float32))
    assert outliers.select_outliers(stats, 0).size == 0
    with pytest.raises(ValueError):
        outliers.select_outliers(stats, 5)


# ---------------------------------------------------------------------------
# weight clipping
# ---------------------------------------------------------------------------


def test_clipping_never_worse_than_unclipped():
    r = rng(2)
    w = r.normal(size=(16, 64)).astype(np.float32)
    w[0, 0] = 40.0  # one huge weight outlier
    unclipped = np.max(np.abs(w), axis=1) / 7
    clipped = clipping.search_clip_scale(w, 4)
    assert clipping.clip_error(w, 4, clipped) <= clipping.clip_error(w, 4, unclipped) + 1e-6


def test_clipping_shrinks_scale_with_weight_outlier():
    """A moderate weight outlier (8σ) makes clipping strictly profitable."""
    r = rng(3)
    w = r.normal(size=(4, 128)).astype(np.float32)
    w[:, 0] = 8.0
    clipped = clipping.search_clip_scale(w, 4)
    unclipped = np.max(np.abs(w), axis=1) / 7
    assert (clipped < unclipped - 1e-6).all()
    assert clipping.clip_error(w, 4, clipped) < clipping.clip_error(w, 4, unclipped)


# ---------------------------------------------------------------------------
# GPTQ
# ---------------------------------------------------------------------------


def layer_output_error(w_hat, w, x):
    """‖X (W_hat - W)^T‖² — the objective GPTQ minimizes."""
    d = (w_hat - w).astype(np.float64)
    return float(np.sum((x.astype(np.float64) @ d.T) ** 2))


@pytest.mark.parametrize("bits", [4, 8])
def test_gptq_beats_rtn_on_layer_output(bits):
    r = rng(4)
    n, k, t = 32, 64, 512
    w = r.normal(size=(n, k)).astype(np.float32)
    x = make_calib(r, t, k)
    h = gptq.hessian_from_calib(x)
    qw_g, _ = gptq.gptq_quantize(w, h, gptq.GPTQConfig(bits=bits, n_outlier=0))
    qw_r = baselines.rtn_quantize(w, bits, 0)
    e_g = layer_output_error(gptq.dequantized_weight(qw_g), w, x)
    e_r = layer_output_error(gptq.dequantized_weight(qw_r), w, x)
    assert e_g < e_r


def test_gptq_outlier_columns_absorb_error():
    """With outliers, GPTQ's layer-output error must shrink further."""
    r = rng(5)
    n, k, t, n_out = 24, 64, 512, 8
    w = r.normal(size=(n, k)).astype(np.float32)
    x = make_calib(r, t, k, outlier_idx=tuple(range(k - n_out, k)))
    h = gptq.hessian_from_calib(x)
    qw0, _ = gptq.gptq_quantize(w, h, gptq.GPTQConfig(bits=4, n_outlier=0))
    qw1, _ = gptq.gptq_quantize(w, h, gptq.GPTQConfig(bits=4, n_outlier=n_out))
    e0 = layer_output_error(gptq.dequantized_weight(qw0), w, x)
    e1 = layer_output_error(gptq.dequantized_weight(qw1), w, x)
    assert e1 < e0


def test_gptq_fp_columns_differ_from_original():
    """Outlier FP columns must be error-compensated, not copied verbatim."""
    r = rng(6)
    w = r.normal(size=(16, 32)).astype(np.float32)
    x = make_calib(r, 256, 32)
    h = gptq.hessian_from_calib(x)
    qw, _ = gptq.gptq_quantize(w, h, gptq.GPTQConfig(bits=4, n_outlier=4))
    assert not np.allclose(np.asarray(qw.w_fp), w[:, -4:])


def test_gptq_clipping_improves_proxy():
    r = rng(7)
    w = r.normal(size=(16, 64)).astype(np.float32)
    w[:, 5] *= 30.0  # weight outlier inflating the scale
    x = make_calib(r, 256, 64)
    h = gptq.hessian_from_calib(x)
    _, e_plain = gptq.gptq_quantize(w, h, gptq.GPTQConfig(bits=4, clip=False))
    _, e_clip = gptq.gptq_quantize(w, h, gptq.GPTQConfig(bits=4, clip=True))
    assert e_clip <= e_plain


def test_gptq_dead_columns_handled():
    r = rng(8)
    w = r.normal(size=(8, 16)).astype(np.float32)
    x = make_calib(r, 64, 16)
    x[:, 3] = 0.0  # dead feature → zero Hessian row/col
    h = gptq.hessian_from_calib(x)
    qw, _ = gptq.gptq_quantize(w, h, gptq.GPTQConfig(bits=4))
    assert np.isfinite(gptq.dequantized_weight(qw)).all()


def test_gptq_w8_near_lossless():
    r = rng(9)
    w = r.normal(size=(16, 48)).astype(np.float32)
    x = make_calib(r, 256, 48)
    h = gptq.hessian_from_calib(x)
    qw, _ = gptq.gptq_quantize(w, h, gptq.GPTQConfig(bits=8))
    rel = np.abs(gptq.dequantized_weight(qw) - w) / (np.abs(w) + 1e-3)
    assert np.median(rel) < 0.02


# ---------------------------------------------------------------------------
# SparseGPT 2:4 + quant
# ---------------------------------------------------------------------------


def test_sparsegpt_24_pattern_holds():
    r = rng(10)
    w = r.normal(size=(16, 64)).astype(np.float32)
    x = make_calib(r, 256, 64)
    h = gptq.hessian_from_calib(x)
    qw, mask, _ = sparsegpt.sparsegpt_quantize(
        w, h, sparsegpt.SparseGPTConfig(bits=4, n_outlier=0)
    )
    assert sparsegpt.check_24_pattern(mask)
    assert abs(sparsegpt.sparsity_ratio(mask) - 0.5) < 1e-6
    # pruned positions must be exactly zero in the int tensor
    assert (np.asarray(qw.w_int)[~mask] == 0).all()


def test_sparsegpt_outlier_columns_stay_dense():
    r = rng(11)
    n_out = 8
    w = r.normal(size=(16, 64)).astype(np.float32)
    x = make_calib(r, 256, 64, outlier_idx=tuple(range(64 - n_out, 64)))
    h = gptq.hessian_from_calib(x)
    qw, mask, _ = sparsegpt.sparsegpt_quantize(
        w, h, sparsegpt.SparseGPTConfig(bits=4, n_outlier=n_out)
    )
    assert mask.shape[1] == 64 - n_out          # mask covers base only
    assert np.asarray(qw.w_fp).shape[1] == n_out  # outliers dense FP


def test_sparsegpt_beats_magnitude_prune_then_rtn():
    """Joint one-shot must beat naive magnitude-prune → RTN (§4.3.2)."""
    r = rng(12)
    n, k, t = 32, 64, 512
    w = r.normal(size=(n, k)).astype(np.float32)
    x = make_calib(r, t, k)
    h = gptq.hessian_from_calib(x)
    qw, mask, _ = sparsegpt.sparsegpt_quantize(
        w, h, sparsegpt.SparseGPTConfig(bits=4, n_outlier=0)
    )
    e_joint = layer_output_error(gptq.dequantized_weight(qw), w, x)

    # naive: keep the 2 largest |w| per group of 4, then RTN quantize
    wn = w.copy().reshape(n, -1, 4)
    order = np.argsort(np.abs(wn), axis=2)
    naive_mask = np.ones_like(wn, bool)
    i0, i1 = np.ogrid[:n, : wn.shape[1]]
    naive_mask[i0, i1, order[:, :, 0]] = False
    naive_mask[i0, i1, order[:, :, 1]] = False
    w_naive = (wn * naive_mask).reshape(n, k)
    qw_naive = baselines.rtn_quantize(w_naive, 4, 0)
    e_naive = layer_output_error(gptq.dequantized_weight(qw_naive), w, x)
    assert e_joint < e_naive


# ---------------------------------------------------------------------------
# baselines
# ---------------------------------------------------------------------------


def test_smoothquant_flattens_outliers():
    r = rng(13)
    x = make_calib(r, 256, 32, outlier_idx=(4,), outlier_gain=100.0)
    w = r.normal(size=(16, 32)).astype(np.float32)
    s = baselines.smoothquant_scales(outliers.collect_stats(x).linf, w, 0.5)
    xs = baselines.smooth_activations(x, s)
    ratio_before = np.max(np.abs(x[:, 4])) / np.median(np.max(np.abs(x), axis=0))
    ratio_after = np.max(np.abs(xs[:, 4])) / np.median(np.max(np.abs(xs), axis=0))
    assert ratio_after < ratio_before / 3


def test_smoothquant_8bit_preserves_product():
    r = rng(14)
    x = make_calib(r, 128, 32, outlier_idx=(7,))
    w = r.normal(size=(16, 32)).astype(np.float32)
    res = baselines.smoothquant_quantize(w, outliers.collect_stats(x).linf, 8)
    xs = jnp.asarray(baselines.smooth_activations(x, res.smooth_scale))
    y = np.asarray(quik_linear_ref(xs, res.qw))
    rel = np.linalg.norm(y - x @ w.T) / np.linalg.norm(x @ w.T)
    assert rel < 0.05


def test_rtn_roundtrip_bits():
    r = rng(15)
    w = r.normal(size=(8, 32)).astype(np.float32)
    for bits in (4, 8):
        qw = baselines.rtn_quantize(w, bits, 0)
        q = np.asarray(qw.w_int)
        qmax = 2 ** (bits - 1) - 1
        assert q.min() >= -qmax and q.max() <= qmax


# ---------------------------------------------------------------------------
# policy
# ---------------------------------------------------------------------------


def test_policy_down_proj_gets_8bit_and_more_outliers():
    p = policy.QUIK_4B
    plan = p.plan_for("layers.0.mlp.down_proj", 11008)
    assert plan.weight_bits == 8 and plan.act_bits == 8
    assert plan.n_outlier == 896  # 3.5 × 256 (Table 8)
    plan_q = p.plan_for("layers.0.self_attn.q_proj", 4096)
    assert plan_q.weight_bits == 4 and plan_q.n_outlier == 256


def test_policy_zero_outlier_threshold():
    r = rng(16)
    tame = outliers.collect_stats(r.normal(size=(64, 128)).astype(np.float32) * 0.01)
    wild = outliers.collect_stats(make_calib(r, 64, 128, outlier_idx=(0,), outlier_gain=1000))
    p = policy.QuikPolicy(n_outlier=16, zero_outlier_threshold=0.1)
    assert p.plan_for("q_proj", 128, tame).n_outlier == 0
    assert p.plan_for("q_proj", 128, wild).n_outlier == 16


def test_policy_outlier_clamped_to_fraction():
    p = policy.QuikPolicy(n_outlier=256, max_outlier_frac=0.25)
    assert p.plan_for("q_proj", 64).n_outlier == 16


def test_policy_sparse_dense_exceptions():
    p = policy.QuikPolicy(sparsity="2:4", sparse_dense_layers=("mlp",))
    assert p.plan_for("mlp.up_proj", 512).sparsity == "dense"
    assert p.plan_for("self_attn.q_proj", 512).sparsity == "2:4"


def test_fp16_policy_not_quantized():
    plan = policy.FP16.plan_for("q_proj", 512)
    assert not plan.is_quantized


# ---------------------------------------------------------------------------
# quantize_linear end-to-end (scheme matrix)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize(
    "scheme", ["quik", "rtn", "smoothquant", "gptq_wonly", "sparse_quik", "fp16"]
)
def test_quantize_linear_schemes_run_and_approximate(scheme):
    r = rng(17)
    n, k, t = 24, 64, 256
    w = r.normal(size=(n, k)).astype(np.float32)
    b = r.normal(size=n).astype(np.float32)
    x = make_calib(r, t, k, outlier_idx=(3, 40))
    plan = policy.LayerPlan(
        weight_bits=16 if scheme == "fp16" else 4,
        act_bits=16 if scheme in ("fp16", "gptq_wonly") else 4,
        n_outlier=0 if scheme == "smoothquant" else 8,
    )
    ql = quantize.quantize_linear(w, x, plan, scheme=scheme, bias=b)
    xt = jnp.asarray(x[:32])
    y = np.asarray(ql(xt))
    exact = x[:32] @ w.T + b
    rel = np.linalg.norm(y - exact) / np.linalg.norm(exact)
    # fp16 exact; weight-only very tight; 4-bit schemes loose but sane
    budget = {"fp16": 1e-6, "gptq_wonly": 0.05, "quik": 0.2, "rtn": 0.3,
              "smoothquant": 0.6, "sparse_quik": 0.6}[scheme]
    assert rel < budget, f"{scheme}: rel={rel}"


def test_quantize_linear_quik_beats_rtn_with_outliers():
    r = rng(18)
    n, k, t = 32, 96, 512
    w = r.normal(size=(n, k)).astype(np.float32)
    x = make_calib(r, t, k, outlier_idx=(1, 2, 50), outlier_gain=30.0)
    plan = policy.LayerPlan(weight_bits=4, act_bits=4, n_outlier=8)
    y_exact = x[:64] @ w.T
    errs = {}
    for scheme in ("quik", "rtn"):
        ql = quantize.quantize_linear(w, x, plan, scheme=scheme)
        y = np.asarray(ql(jnp.asarray(x[:64])))
        errs[scheme] = np.linalg.norm(y - y_exact)
    assert errs["quik"] < errs["rtn"]


def test_quantized_linear_kernel_path_matches_ref_path():
    """use_kernels=True (Pallas, what AOT lowers) ≡ jnp oracle path."""
    r = rng(19)
    w = r.normal(size=(16, 48)).astype(np.float32)
    x = make_calib(r, 128, 48, outlier_idx=(5,))
    plan = policy.LayerPlan(weight_bits=4, act_bits=4, n_outlier=4)
    ql = quantize.quantize_linear(w, x, plan, scheme="quik")
    xt = jnp.asarray(x[:16])
    np.testing.assert_allclose(
        np.asarray(ql(xt, use_kernels=True)),
        np.asarray(ql(xt, use_kernels=False)),
        rtol=2e-4, atol=2e-4,
    )


@settings(max_examples=10, deadline=None)
@given(
    bits=st.sampled_from([4, 8]),
    n_outlier=st.sampled_from([0, 4, 16]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_gptq_quantized_range(bits, n_outlier, seed):
    r = rng(seed)
    w = r.normal(size=(8, 32)).astype(np.float32)
    x = r.normal(size=(128, 32)).astype(np.float32)
    h = gptq.hessian_from_calib(x)
    qw, _ = gptq.gptq_quantize(w, h, gptq.GPTQConfig(bits=bits, n_outlier=n_outlier))
    q = np.asarray(qw.w_int)
    qmax = 2 ** (bits - 1) - 1
    assert q.min() >= -qmax and q.max() <= qmax
    assert np.asarray(qw.w_fp).shape == (8, n_outlier)
