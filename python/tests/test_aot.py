"""AOT export path: HLO text, weight blobs, manifests, goldens.

Uses an ultra-tiny config so the full train→quantize→lower→write pipeline
runs in seconds, into a temp directory.
"""

import json
import pathlib

import numpy as np
import jax.numpy as jnp
import pytest

from compile import aot, data, model, train
from compile.modeling import common
from compile.quik import policy


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    cfg = common.ModelConfig(
        family="llama", vocab=data.VOCAB_SIZE, d_model=32, n_layers=2,
        n_heads=2, d_ff=48, max_seq=64, n_seeded_outliers=2, outlier_gain=8.0,
    )
    params, _ = train.train(cfg, steps=8, batch=4, seq=32,
                            corpus_tokens=10_000, log_every=0,
                            name="pytest-aot")
    calib = data.calibration_sequences("pile", 4, 32, seed=0)[:, :-1]
    ci = model.calibrate(params, cfg, calib, max_rows=256)
    qm = model.quantize_model(params, cfg, ci, policy.QuikPolicy(n_outlier=4))

    fp_tree, _ = aot.fp16_export_tree(params)
    q_tree, q_meta = aot.quik_export_tree(qm)
    fp_spec = aot.export_artifact("t_fp16", cfg, fp_tree, None, 1, 8, out)
    q_spec = aot.export_artifact("t_quik", cfg, q_tree, q_meta, 1, 8, out)
    return out, cfg, fp_spec, q_spec, params


def test_hlo_text_is_parseable_hlo(exported):
    out, _, fp_spec, q_spec, _ = exported
    for spec in (fp_spec, q_spec):
        text = (out / spec["hlo"]).read_text()
        assert text.startswith("HloModule"), text[:60]
        assert "ENTRY" in text


def test_weights_are_parameters_not_constants(exported):
    """The HLO must take every weight as a parameter (no giant constants)."""
    out, _, fp_spec, _, params = exported
    text = (out / fp_spec["hlo"]).read_text()
    n_params = text.count("parameter(")
    # weights + tokens + cache_k + cache_v + cache_len
    assert n_params >= len(fp_spec["params"]) + 4
    # no embedded weight-sized f32 constants: the file stays small
    assert len(text) < 2_000_000


def test_weight_blob_matches_manifest(exported):
    out, _, fp_spec, q_spec, _ = exported
    for spec in (fp_spec, q_spec):
        blob = (out / spec["weights"]).read_bytes()
        total = sum(p["nbytes"] for p in spec["params"])
        assert len(blob) == total
        # offsets are contiguous and ordered
        off = 0
        for p in spec["params"]:
            assert p["offset"] == off
            assert p["nbytes"] == int(np.prod(p["shape"])) * (1 if p["dtype"] == "s8" else 4)
            off += p["nbytes"]


def test_quik_blob_smaller_than_fp16(exported):
    _, _, fp_spec, q_spec, _ = exported
    fp_bytes = sum(p["nbytes"] for p in fp_spec["params"])
    q_bytes = sum(p["nbytes"] for p in q_spec["params"])
    assert q_bytes < fp_bytes * 0.7, (q_bytes, fp_bytes)


def test_golden_file_consistent(exported):
    out, cfg, fp_spec, _, params = exported
    g = fp_spec["golden"]
    blob = (out / g["file"]).read_bytes()
    n_tok = int(np.prod(g["tokens_shape"]))
    n_log = int(np.prod(g["logits_shape"]))
    assert len(blob) == 4 * (n_tok + n_log)
    tokens = np.frombuffer(blob[: n_tok * 4], np.int32).reshape(g["tokens_shape"])
    logits = np.frombuffer(blob[n_tok * 4 :], np.float32).reshape(g["logits_shape"])
    # re-run the forward in python: must match the stored golden
    ck = jnp.zeros((cfg.n_layers, 1, cfg.n_heads, cfg.max_seq, cfg.d_head))
    want, _, _ = common.forward_with_cache(
        params, jnp.asarray(tokens), cfg, ck, jnp.zeros_like(ck), jnp.int32(0)
    )
    np.testing.assert_allclose(logits, np.asarray(want), rtol=1e-5, atol=1e-5)


def test_quik_export_tree_strips_fp_weights(exported):
    """Quantized layers must not ship their FP16 weight in the artifact."""
    _, _, _, q_spec, _ = exported
    names = [p["name"] for p in q_spec["params"]]
    # every 'w_int' present; no bare '<linear>.w' for quantized layers
    assert any("w_int" in n for n in names)
    for n in names:
        if n.endswith(".w"):
            # only allowed for fp16-fallback layers; QUIK_4B quantizes all
            raise AssertionError(f"FP weight leaked into quik artifact: {n}")


def test_dtypes_are_supported_set(exported):
    _, _, fp_spec, q_spec, _ = exported
    for spec in (fp_spec, q_spec):
        for p in spec["params"] + spec["inputs"] + spec["outputs"]:
            assert p["dtype"] in ("f32", "s32", "s8"), p
