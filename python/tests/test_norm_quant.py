"""Fused RMSNorm+split+quantize kernel vs its unfused oracle."""

import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import norm_quant


def rng(seed):
    return np.random.default_rng(seed)


@pytest.mark.parametrize("bits", [4, 8])
@pytest.mark.parametrize("n_outlier", [0, 4, 16])
def test_fused_matches_unfused(bits, n_outlier):
    r = rng(0)
    m, d = 40, 64
    x = jnp.asarray(r.normal(size=(m, d)).astype(np.float32))
    g = jnp.asarray(r.uniform(0.5, 2.0, d).astype(np.float32))
    qa_f, fp_f = norm_quant.norm_split_quantize(x, g, n_outlier, bits, block_m=16)
    qa_r, fp_r = norm_quant.norm_split_quantize_ref(x, g, n_outlier, bits)
    diff = np.abs(np.asarray(qa_f.q, np.int32) - np.asarray(qa_r.q, np.int32))
    assert diff.max() <= 1 and (diff > 0).mean() < 1e-3  # rounding ties only
    np.testing.assert_allclose(qa_f.scale, qa_r.scale, rtol=1e-5)
    np.testing.assert_allclose(qa_f.zero, qa_r.zero, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(fp_f), np.asarray(fp_r), rtol=1e-6)


def test_permutation_commutes_with_rmsnorm():
    """Permuting features before RMSNorm == permuting after (exactness of
    the outlier-permuted layout the fused kernel assumes)."""
    r = rng(1)
    m, d = 8, 32
    x = r.normal(size=(m, d)).astype(np.float32)
    g = r.uniform(0.5, 2.0, d).astype(np.float32)
    perm = r.permutation(d)
    qa_a, fp_a = norm_quant.norm_split_quantize_ref(
        jnp.asarray(x[:, perm]), jnp.asarray(g[perm]), 4, 4
    )
    # unpermuted norm, then permute, then split+quant
    ms = np.mean(x * x, axis=1, keepdims=True)
    xn = (x / np.sqrt(ms + 1e-6) * g)[:, perm]
    from compile.kernels.ref import quantize_acts_ref

    qa_b = quantize_acts_ref(jnp.asarray(xn[:, :28]), 4)
    np.testing.assert_allclose(qa_a.scale, qa_b.scale, rtol=1e-5)
    np.testing.assert_array_equal(np.asarray(qa_a.q), np.asarray(qa_b.q))
    np.testing.assert_allclose(np.asarray(fp_a), xn[:, 28:], rtol=1e-5)


def test_outlier_gain_lands_in_fp_columns():
    """A large gain on outlier channels must not touch base quantization."""
    r = rng(2)
    m, d, n_out = 16, 32, 4
    x = jnp.asarray(r.normal(size=(m, d)).astype(np.float32))
    g = np.ones(d, np.float32)
    g[-n_out:] = 100.0
    qa, fp = norm_quant.norm_split_quantize(x, jnp.asarray(g), n_out, 4, block_m=8)
    g1 = np.ones(d, np.float32)
    qa1, _ = norm_quant.norm_split_quantize(x, jnp.asarray(g1), n_out, 4, block_m=8)
    np.testing.assert_array_equal(np.asarray(qa.q), np.asarray(qa1.q))
    assert np.abs(np.asarray(fp)).max() > 10.0


@settings(max_examples=15, deadline=None)
@given(
    m=st.integers(1, 48),
    d=st.integers(8, 96),
    frac=st.floats(0.0, 0.4),
    bits=st.sampled_from([4, 8]),
    seed=st.integers(0, 2**31 - 1),
)
def test_hypothesis_fused_norm_quant(m, d, frac, bits, seed):
    r = rng(seed)
    n_outlier = int(d * frac)
    if d - n_outlier < 2:
        n_outlier = 0
    x = jnp.asarray(r.normal(size=(m, d)).astype(np.float32))
    g = jnp.asarray(r.uniform(0.5, 2.0, d).astype(np.float32))
    qa_f, fp_f = norm_quant.norm_split_quantize(x, g, n_outlier, bits, block_m=16)
    qa_r, fp_r = norm_quant.norm_split_quantize_ref(x, g, n_outlier, bits)
    diff = np.abs(np.asarray(qa_f.q, np.int32) - np.asarray(qa_r.q, np.int32))
    assert diff.max() <= 1
    np.testing.assert_allclose(qa_f.scale, qa_r.scale, rtol=1e-5)
    np.testing.assert_allclose(np.asarray(fp_f), np.asarray(fp_r), rtol=1e-6)
