"""Tiny-model pretraining on the synthetic corpus (build-time only).

The accuracy side of the reproduction needs *trained* models: activation
outliers and the down-projection variance spike (Fig. 10) are properties of
trained transformers, not of random init.  This module pretrains the
``modeling.presets.TINY`` zoo on the synthetic corpus with a hand-rolled
AdamW (no optax in the image) and caches checkpoints under
``artifacts/checkpoints/`` keyed by a config/corpus fingerprint, so
``make artifacts`` trains each model exactly once.
"""

from __future__ import annotations

import functools
import hashlib
import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp

from . import data
from .modeling import common, presets

CKPT_DIR = pathlib.Path(__file__).resolve().parents[2] / "artifacts" / "checkpoints"


# ---------------------------------------------------------------------------
# loss / optimizer
# ---------------------------------------------------------------------------


def loss_fn(params, batch, cfg):
    """Mean next-token cross-entropy over a ``[B, S+1]`` batch."""
    inputs, targets = batch[:, :-1], batch[:, 1:]
    logits, _ = common.forward(params, inputs, cfg)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def adamw_init(params):
    zeros = jax.tree.map(jnp.zeros_like, params)
    return {"m": zeros, "v": jax.tree.map(jnp.zeros_like, params), "t": jnp.zeros((), jnp.int32)}


def adamw_update(params, grads, state, lr, b1=0.9, b2=0.999, eps=1e-8, wd=0.01):
    t = state["t"] + 1
    m = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g, state["m"], grads)
    v = jax.tree.map(lambda v, g: b2 * v + (1 - b2) * g * g, state["v"], grads)
    bc1 = 1 - b1 ** t.astype(jnp.float32)
    bc2 = 1 - b2 ** t.astype(jnp.float32)

    def upd(p, m_, v_):
        step = lr * (m_ / bc1) / (jnp.sqrt(v_ / bc2) + eps)
        return p - step - lr * wd * p

    return jax.tree.map(upd, params, m, v), {"m": m, "v": v, "t": t}


@functools.partial(jax.jit, static_argnames=("cfg",))
def train_step(params, opt_state, batch, lr, cfg):
    loss, grads = jax.value_and_grad(loss_fn)(params, batch, cfg)
    params, opt_state = adamw_update(params, grads, opt_state, lr)
    return params, opt_state, loss


# ---------------------------------------------------------------------------
# checkpoint (de)serialization
# ---------------------------------------------------------------------------


def _flatten(params, prefix=""):
    out = {}
    if isinstance(params, dict):
        for k, v in params.items():
            out.update(_flatten(v, f"{prefix}{k}."))
    elif isinstance(params, list):
        for i, v in enumerate(params):
            out.update(_flatten(v, f"{prefix}{i}."))
    else:
        out[prefix[:-1]] = np.asarray(params)
    return out


def _unflatten(flat: dict, cfg: common.ModelConfig):
    """Rebuild the nested param dict from flat dotted keys."""
    params = common.init_params(cfg, seed=0)  # template structure

    def set_path(obj, path, value):
        key = path[0]
        if isinstance(obj, list):
            key = int(key)
        if len(path) == 1:
            obj[key] = jnp.asarray(value)
        else:
            set_path(obj[key], path[1:], value)

    for k, v in flat.items():
        set_path(params, k.split("."), v)
    return params


def fingerprint(cfg: common.ModelConfig, steps: int, seed: int) -> str:
    blob = json.dumps([cfg.__dict__, steps, seed], sort_keys=True, default=str)
    return hashlib.sha256(blob.encode()).hexdigest()[:12]


# ---------------------------------------------------------------------------
# the trainer
# ---------------------------------------------------------------------------


def train(
    cfg: common.ModelConfig,
    steps: int = 300,
    batch: int = 16,
    seq: int = 128,
    lr_max: float = 3e-3,
    seed: int = 0,
    corpus_tokens: int = 400_000,
    log_every: int = 50,
    name: str = "model",
) -> tuple[common.Params, list[float]]:
    """Pretrain; returns ``(params, loss_curve)``.  Cached on disk."""
    CKPT_DIR.mkdir(parents=True, exist_ok=True)
    fp = fingerprint(cfg, steps, seed)
    path = CKPT_DIR / f"{name}-{fp}.npz"
    if path.exists():
        flat = dict(np.load(path, allow_pickle=False))
        losses = [float(x) for x in flat.pop("__loss_curve__")]
        return _unflatten(flat, cfg), losses

    corpus = data.make_corpus("train", corpus_tokens, seed=seed)
    params = common.init_params(cfg, seed=seed)
    opt_state = adamw_init(params)
    losses = []
    warmup = max(1, steps // 20)
    for step in range(steps):
        # linear warmup + cosine decay
        if step < warmup:
            lr = lr_max * (step + 1) / warmup
        else:
            frac = (step - warmup) / max(1, steps - warmup)
            lr = lr_max * 0.5 * (1 + np.cos(np.pi * frac))
        b = jnp.asarray(data.batches(corpus, batch, seq, seed=seed * 100_003 + step))
        params, opt_state, loss = train_step(params, opt_state, b, lr, cfg)
        losses.append(float(loss))
        if log_every and (step % log_every == 0 or step == steps - 1):
            print(f"[train {name}] step {step:4d}  lr {lr:.2e}  loss {losses[-1]:.4f}")

    flat = _flatten(params)
    flat["__loss_curve__"] = np.asarray(losses, np.float32)
    np.savez(path, **flat)
    return params, losses


def load_or_train(name: str, steps: int = 300, seed: int = 0, **kw):
    """Train-or-load one of the ``presets.TINY`` models by name."""
    cfg = presets.TINY[name]
    params, losses = train(cfg, steps=steps, seed=seed, name=name, **kw)
    return cfg, params, losses
