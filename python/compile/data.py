"""Synthetic multi-domain corpus — stand-in for Pile / C4 / WikiText2 / PTB.

The paper's pipeline touches four datasets: Pile (outlier calibration, 512
random sentences), C4 (GPTQ calibration, 128×2048-token samples), and
WikiText2 / PTB / C4 (perplexity evaluation).  None are available here, so
we generate a corpus with the statistical properties that matter for the
reproduction (DESIGN.md §2 Substitutions):

* **Zipfian unigram marginals** — like natural text, a few tokens dominate;
* **topic structure** — a mixture of per-topic first-order Markov chains
  with sticky topic switching, so a trained model develops feature
  directions that differ in magnitude (the raw material for activation
  outliers);
* **distinct splits** — each named split mixes topics with different
  weights and uses a disjoint seed stream, standing in for the paper's
  train/calibration/eval dataset separation.

Splits: ``train`` (pretraining), ``pile`` (outlier calibration), ``c4``
(GPTQ calibration + C4 eval), ``wikitext2`` and ``ptb`` (eval).
"""

from __future__ import annotations

import functools

import numpy as np

VOCAB_SIZE = 256
N_TOPICS = 8

# Per-split (seed offset, topic temperature): eval splits lean on different
# topic mixtures so they are genuinely held-out distributions.
SPLITS = {
    "train": (0, 1.0),
    "pile": (1, 1.0),
    "c4": (2, 0.8),
    "wikitext2": (3, 1.2),
    "ptb": (4, 1.5),
}


def _zipf_probs(n: int, s: float = 1.1) -> np.ndarray:
    ranks = np.arange(1, n + 1, dtype=np.float64)
    p = ranks ** (-s)
    return p / p.sum()


@functools.lru_cache(maxsize=None)
def _topic_chains(seed: int = 1234) -> np.ndarray:
    """Per-topic Markov transition matrices ``[T, V, V]`` (row-stochastic).

    Each topic prefers a different band of the vocabulary, superimposed on
    a shared Zipfian backbone — so topics are distinguishable but share the
    head of the distribution, like real text domains.
    """
    r = np.random.default_rng(seed)
    zipf = _zipf_probs(VOCAB_SIZE)
    chains = np.empty((N_TOPICS, VOCAB_SIZE, VOCAB_SIZE), np.float64)
    for t in range(N_TOPICS):
        # Topic bias: a smooth bump over a band of the vocab.
        centers = (np.arange(VOCAB_SIZE) - (t + 0.5) * VOCAB_SIZE / N_TOPICS)
        bias = np.exp(-0.5 * (centers / (VOCAB_SIZE / N_TOPICS)) ** 2)
        base = zipf * (0.3 + bias)
        # Row-dependent perturbation makes it a true first-order chain.
        pert = r.gamma(2.0, size=(VOCAB_SIZE, VOCAB_SIZE))
        m = base[None, :] * pert
        chains[t] = m / m.sum(axis=1, keepdims=True)
    return chains


def make_corpus(split: str, n_tokens: int, seed: int = 0) -> np.ndarray:
    """Generate ``n_tokens`` of the given split as ``int32[n_tokens]``."""
    if split not in SPLITS:
        raise KeyError(f"unknown split {split!r}; have {sorted(SPLITS)}")
    seed_off, temp = SPLITS[split]
    r = np.random.default_rng(977 * (seed_off + 1) + seed)
    chains = _topic_chains()

    # Split-specific topic mixture (temperature-skewed).
    logits = r.normal(size=N_TOPICS) * temp
    topic_probs = np.exp(logits - logits.max())
    topic_probs /= topic_probs.sum()

    # Pre-computed per-topic CDFs + a pre-drawn uniform stream make the
    # sequential sampling loop a cheap searchsorted per token.
    cdfs = np.cumsum(chains, axis=2)
    uniforms = r.random(n_tokens)
    out = np.empty(n_tokens, np.int32)
    topic = int(r.choice(N_TOPICS, p=topic_probs))
    tok = int(r.integers(VOCAB_SIZE))
    stay = 0.995  # sticky topics → long coherent spans
    i = 0
    while i < n_tokens:
        run = int(min(r.geometric(1 - stay), n_tokens - i))
        cdf = cdfs[topic]
        for j in range(i, i + run):
            tok = min(int(np.searchsorted(cdf[tok], uniforms[j])), VOCAB_SIZE - 1)
            out[j] = tok
        i += run
        topic = int(r.choice(N_TOPICS, p=topic_probs))
    return out


def batches(
    tokens: np.ndarray, batch: int, seq: int, seed: int = 0
) -> "np.ndarray":
    """Random ``[batch, seq+1]`` windows (inputs + next-token targets)."""
    r = np.random.default_rng(seed)
    starts = r.integers(0, len(tokens) - seq - 1, size=batch)
    return np.stack([tokens[s : s + seq + 1] for s in starts]).astype(np.int32)


def eval_windows(tokens: np.ndarray, seq: int) -> np.ndarray:
    """Deterministic non-overlapping eval windows ``[n, seq+1]``."""
    n = (len(tokens) - 1) // seq
    out = np.empty((n, seq + 1), np.int32)
    for i in range(n):
        out[i] = tokens[i * seq : i * seq + seq + 1]
    return out


def calibration_sequences(
    split: str, n_seq: int, seq: int, seed: int = 0
) -> np.ndarray:
    """Paper-style calibration draws (e.g. 512 Pile sentences, 128 C4 seqs)."""
    corpus = make_corpus(split, n_seq * (seq + 1) + seq, seed=seed)
    return batches(corpus, n_seq, seq, seed=seed + 1)
