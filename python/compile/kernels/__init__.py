"""L1: Pallas kernels for the QUIK mixed-precision pipeline.

Modules:
  ref          pure-jnp correctness oracles (ground truth for pytest)
  quant        fused per-token asymmetric quantization (+ v1 unfused baseline)
  matmul       INT4/INT8 tiled matmul with fused dequantization epilogue
  quik_linear  the full Algorithm-1 linear layer composing the above
  norm_quant   fused RMSNorm + split + quantize (extension, DESIGN.md)
"""
from . import matmul, norm_quant, quant, quik_linear, ref  # noqa: F401
