"""Pallas quantization kernels — the paper's fused quantization pipeline.

The paper's CUDA implementation (§3.4 "Quantization Fusion") assigns each
input row to a CUDA block and performs three logical passes over a
register/shared-memory resident row: min/max reduction over the non-outlier
elements, quantization of the non-outliers, and moving the outliers to a
separate buffer.  The TPU/Pallas rethink keeps the same HBM↔scratchpad
schedule but expresses it with a ``BlockSpec`` over token tiles: each grid
step holds a ``(block_m, K)`` activation tile in VMEM and performs the
reduce + quantize + outlier-move entirely in-register before a single
write-out.  (See DESIGN.md §3 Hardware adaptation.)

Three pipeline variants reproduce the paper's Figure 6 kernel versions:

* ``quantize_acts_v1``   — deliberately *unfused*: separate passes for the
  outlier split, the min/max metadata scan and the quantization write, each
  materializing an intermediate (paper's "version 1").
* ``quantize_acts``      — the fused single-pass Pallas kernel ("version 2"
  quantization; also used by version 3).
* ``split_quantize``     — fused split + quantize: one VMEM pass emits the
  packed base tensor, the FP outlier slice and the per-token metadata.

All kernels run under ``interpret=True``: real-TPU lowering emits Mosaic
custom-calls the CPU PJRT plugin cannot execute.  INT4/INT8 values are
carried in int8 containers; the byte-exact nibble packing used for memory
accounting lives in ``rust/src/quant/int4.rs``.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import (
    SCALE_EPS,
    QuantizedActs,
    act_qrange,
    half_range,
)

# Default token-tile height.  The paper tunes "rows per CUDA block" to 8-32
# (§3.4 Parallelization Tuning); block_m plays the same role for the VMEM
# tile and 64 rows keeps the tile ≪ 16 MB VMEM for K up to 28k (f32).
DEFAULT_BLOCK_M = 64


def _pad_rows(x: jnp.ndarray, block_m: int) -> tuple[jnp.ndarray, int]:
    """Zero-pad the token axis up to a multiple of ``block_m``."""
    m = x.shape[0]
    pad = (-m) % block_m
    if pad:
        x = jnp.pad(x, ((0, pad), (0, 0)))
    return x, m


def _quant_block(x: jnp.ndarray, bits: int) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Quantize a resident ``(bm, K)`` tile; returns (q, scale, zero)."""
    lo = jnp.min(x, axis=1)
    hi = jnp.max(x, axis=1)
    scale = jnp.maximum((hi - lo) / float((1 << bits) - 1), SCALE_EPS)
    q = jnp.round((x - lo[:, None]) / scale[:, None]) - half_range(bits)
    qmin, qmax = act_qrange(bits)
    return jnp.clip(q, qmin, qmax).astype(jnp.int8), scale, lo


def _quant_kernel(x_ref, q_ref, scale_ref, zero_ref, *, bits: int):
    """Fused pass: reduce → quantize, all while the tile is VMEM-resident."""
    q, scale, zero = _quant_block(x_ref[...], bits)
    q_ref[...] = q
    scale_ref[...] = scale
    zero_ref[...] = zero


@functools.partial(jax.jit, static_argnames=("bits", "block_m"))
def quantize_acts(
    x: jnp.ndarray, bits: int, block_m: int = DEFAULT_BLOCK_M
) -> QuantizedActs:
    """Fused per-token asymmetric quantization (paper v2/v3 quant kernel).

    One read of ``x`` from HBM, one write of the int output + metadata —
    versus v1's two reads (min/max scan, quantize) and an extra round-trip
    for the split (see ``quantize_acts_v1``).

    Args:
      x: ``f32[M, K_base]`` non-outlier activation block (outliers already
        permuted out by the caller; use :func:`split_quantize` to fuse the
        split too).
      bits: activation bit width (4 or 8).
      block_m: token-tile height (the "rows per block" tuning knob).

    Returns:
      :class:`QuantizedActs` with ``q`` int8-carried INT``bits`` values.
    """
    xp, m = _pad_rows(x, block_m)
    mp, k = xp.shape
    q, scale, zero = pl.pallas_call(
        functools.partial(_quant_kernel, bits=bits),
        grid=(mp // block_m,),
        in_specs=[pl.BlockSpec((block_m, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_m, k), lambda i: (i, 0)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, k), jnp.int8),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ],
        interpret=True,
    )(xp)
    return QuantizedActs(q=q[:m], scale=scale[:m], zero=zero[:m])


def _split_quant_kernel(
    x_ref, q_ref, fp_ref, scale_ref, zero_ref, *, bits: int, k_base: int
):
    """Fused split + quantize over a column-permuted ``(bm, K)`` tile.

    The outlier columns are the trailing ``K - k_base`` columns (paper's
    permuted layout, Fig. 4), so the "split" is a static in-register slice:
    metadata reduction and quantization read only ``x[:, :k_base]`` while the
    outlier move is a copy of ``x[:, k_base:]`` — the three CUDA passes of
    §3.4 collapsed into one VMEM visit.
    """
    x = x_ref[...]
    base = x[:, :k_base]
    q, scale, zero = _quant_block(base, bits)
    q_ref[...] = q
    fp_ref[...] = x[:, k_base:]
    scale_ref[...] = scale
    zero_ref[...] = zero


@functools.partial(jax.jit, static_argnames=("n_outlier", "bits", "block_m"))
def split_quantize(
    x: jnp.ndarray,
    n_outlier: int,
    bits: int,
    block_m: int = DEFAULT_BLOCK_M,
) -> tuple[QuantizedActs, jnp.ndarray]:
    """Fused outlier split + per-token quantization (Algorithm 1 lines 3-4).

    Args:
      x: ``f32[M, K]`` column-permuted activations, outliers last.
      n_outlier: number of trailing outlier columns kept in full precision.

    Returns:
      ``(QuantizedActs over the base block, f32[M, n_outlier] outliers)``.
    """
    if n_outlier == 0:
        return quantize_acts(x, bits, block_m), x[:, :0]
    xp, m = _pad_rows(x, block_m)
    mp, k = xp.shape
    k_base = k - n_outlier
    q, fp, scale, zero = pl.pallas_call(
        functools.partial(_split_quant_kernel, bits=bits, k_base=k_base),
        grid=(mp // block_m,),
        in_specs=[pl.BlockSpec((block_m, k), lambda i: (i, 0))],
        out_specs=[
            pl.BlockSpec((block_m, k_base), lambda i: (i, 0)),
            pl.BlockSpec((block_m, n_outlier), lambda i: (i, 0)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, k_base), jnp.int8),
            jax.ShapeDtypeStruct((mp, n_outlier), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ],
        interpret=True,
    )(xp)
    return QuantizedActs(q=q[:m], scale=scale[:m], zero=zero[:m]), fp[:m]


@functools.partial(jax.jit, static_argnames=("n_outlier", "bits"))
def split_quantize_v1(
    x: jnp.ndarray, n_outlier: int, bits: int
) -> tuple[QuantizedActs, jnp.ndarray]:
    """Unfused v1 pipeline: split, scan and quantize as *separate* passes.

    Mirrors the paper's naive implementation (§3.4): one read+write for the
    outlier part, one read+write for the base part, two more reads for the
    per-token min/max and a final read+write for quantization.  Numerically
    identical to :func:`split_quantize`; exists as the Figure 6 "version 1"
    baseline and as a cross-check of the fused kernels.
    """
    k_base = x.shape[1] - n_outlier
    # Pass 1+2: materialize the split (base copy + outlier copy).
    base = jnp.asarray(x[:, :k_base])
    fp = jnp.asarray(x[:, k_base:])
    # Pass 3+4: metadata scans.
    lo = jnp.min(base, axis=1)
    hi = jnp.max(base, axis=1)
    scale = jnp.maximum((hi - lo) / float((1 << bits) - 1), SCALE_EPS)
    # Pass 5: quantization write.
    q = jnp.round((base - lo[:, None]) / scale[:, None]) - half_range(bits)
    qmin, qmax = act_qrange(bits)
    q = jnp.clip(q, qmin, qmax).astype(jnp.int8)
    return QuantizedActs(q=q, scale=scale, zero=lo), fp
