"""Pure-jnp reference oracles for the QUIK kernels.

Everything in this module is deliberately written as straight-line jnp with
no Pallas, no fusion and no cleverness: it is the correctness ground truth
that ``pytest python/tests`` checks the Pallas kernels (and, via golden
files, the Rust substrate) against.

Quantization scheme (paper §3.3):

* **Activations** — asymmetric, per token (row).  For a row ``x`` and bit
  width ``b``::

      scale = (max(x) - min(x)) / (2^b - 1)
      zero  = min(x)
      q     = round((x - zero) / scale) - halfRange          # signed
      halfRange = 2^(b-1)

  so ``q`` lies in ``[-2^(b-1), 2^(b-1) - 1]`` and the reconstruction is
  ``x ≈ scale * (q + halfRange) + zero``.

* **Weights** — symmetric, per output channel::

      scale = max(|w|) / (2^(b-1) - 1)
      q     = clamp(round(w / scale), -(2^(b-1)-1), 2^(b-1)-1)

* **Dequantization** (paper Eq. 1) — with ``acc = Σ_k wq[n,k] * xq[m,k]``
  accumulated in int32::

      y[m,n] = acc * scaleAct[m] * scaleW[n]
             + (zeroAct[m] + halfRange * scaleAct[m]) * wReduced[n]

  where ``wReduced[n] = scaleW[n] * Σ_k wq[n,k]`` is precomputed offline.

Outlier handling follows the paper's permuted layout: the caller permutes
columns so the ``n_outlier`` outlier features are the *last* columns of both
the activation and the weight matrix; the split is then a plain slice.
"""

from __future__ import annotations

from typing import NamedTuple

import jax.numpy as jnp


def half_range(bits: int) -> int:
    """Signed offset used to re-center unsigned quantized activations."""
    return 1 << (bits - 1)


def act_qrange(bits: int) -> tuple[int, int]:
    """Inclusive signed range for asymmetrically quantized activations."""
    return -(1 << (bits - 1)), (1 << (bits - 1)) - 1


def weight_qmax(bits: int) -> int:
    """Symmetric weight quantization maximum magnitude (e.g. 7 for INT4)."""
    return (1 << (bits - 1)) - 1


# An epsilon floor for scales: a fully-constant token row would otherwise
# produce scale == 0 and NaNs on the divide.
SCALE_EPS = 1e-8


class QuantizedActs(NamedTuple):
    """Per-token asymmetrically quantized activations.

    ``q`` carries INT``bits`` values in an int8 container (interpret-mode
    stand-in for the packed format; see ``rust/src/quant/int4.rs`` for the
    byte-exact packed layout used by the memory model).
    """

    q: jnp.ndarray        # int8[M, K_base]  values in act_qrange(bits)
    scale: jnp.ndarray    # f32[M]
    zero: jnp.ndarray     # f32[M]


class QuantizedWeights(NamedTuple):
    """Offline-quantized QUIK weight package for one linear layer.

    Layout convention matches the paper's Figure 4/5: column-permuted so
    outlier input features occupy the trailing columns.  ``w_int`` covers the
    base (quantized) input features; ``w_fp`` the outlier columns kept in
    full precision.
    """

    w_int: jnp.ndarray      # int8[N, K_base]   symmetric INTb weights
    w_fp: jnp.ndarray       # f32[N, n_outlier] outlier columns (may be 0-wide)
    scale_w: jnp.ndarray    # f32[N]            per-output symmetric scale
    w_reduced: jnp.ndarray  # f32[N]            scale_w * Σ_k w_int[., k]
    bits: int


def quantize_acts_ref(x: jnp.ndarray, bits: int) -> QuantizedActs:
    """Asymmetric per-token quantization of the *base* activation block."""
    lo = jnp.min(x, axis=-1)
    hi = jnp.max(x, axis=-1)
    scale = jnp.maximum((hi - lo) / float((1 << bits) - 1), SCALE_EPS)
    zero = lo
    q = jnp.round((x - zero[:, None]) / scale[:, None]) - half_range(bits)
    qmin, qmax = act_qrange(bits)
    q = jnp.clip(q, qmin, qmax).astype(jnp.int8)
    return QuantizedActs(q=q, scale=scale, zero=zero)


def dequantize_acts_ref(qa: QuantizedActs, bits: int) -> jnp.ndarray:
    """Reconstruct activations — used only by tests, never on the hot path."""
    return (
        qa.scale[:, None] * (qa.q.astype(jnp.float32) + half_range(bits))
        + qa.zero[:, None]
    )


def quantize_weights_ref(
    w: jnp.ndarray, bits: int, n_outlier: int = 0
) -> QuantizedWeights:
    """Symmetric per-output-channel RTN weight quantization.

    ``w`` is ``[N, K]`` *already column-permuted* so the last ``n_outlier``
    input features are outliers; those columns stay FP.  GPTQ-based
    quantization (the accurate path) lives in ``compile.quik.gptq`` and
    produces the same ``QuantizedWeights`` container.
    """
    k_base = w.shape[1] - n_outlier
    w_base = w[:, :k_base]
    w_fp = w[:, k_base:].astype(jnp.float32)
    qmax = weight_qmax(bits)
    scale = jnp.maximum(jnp.max(jnp.abs(w_base), axis=-1) / qmax, SCALE_EPS)
    w_int = jnp.clip(jnp.round(w_base / scale[:, None]), -qmax, qmax).astype(
        jnp.int8
    )
    w_reduced = scale * jnp.sum(w_int.astype(jnp.float32), axis=-1)
    return QuantizedWeights(
        w_int=w_int, w_fp=w_fp, scale_w=scale, w_reduced=w_reduced, bits=bits
    )


def int_matmul_ref(qx: jnp.ndarray, qw: jnp.ndarray) -> jnp.ndarray:
    """INT×INT matmul with int32 accumulation: ``qx[M,K] @ qw[N,K]^T``."""
    return jnp.matmul(
        qx.astype(jnp.int32), qw.astype(jnp.int32).T,
        preferred_element_type=jnp.int32,
    )


def dequantize_ref(
    acc: jnp.ndarray,
    scale_act: jnp.ndarray,
    zero_act: jnp.ndarray,
    scale_w: jnp.ndarray,
    w_reduced: jnp.ndarray,
    bits: int,
) -> jnp.ndarray:
    """Paper Eq. 1 / Algorithm 1 ``Dequantization``: int32 → f32."""
    x = acc.astype(jnp.float32) * scale_act[:, None] * scale_w[None, :]
    shift = zero_act + half_range(bits) * scale_act
    return x + shift[:, None] * w_reduced[None, :]


def quik_linear_ref(
    x: jnp.ndarray,
    qw: QuantizedWeights,
    bias: jnp.ndarray | None = None,
    act_bits: int | None = None,
) -> jnp.ndarray:
    """Full QUIK linear layer, Algorithm 1 ``QUIK Matmul`` (unfused).

    ``x`` is ``[M, K]`` column-permuted (outliers last).  Returns
    ``[M, N] = dequant(intmm(quant(x_base), w_int)) + x_fp @ w_fp^T (+ bias)``.

    ``act_bits`` defaults to the weight bit width (the paper's symmetric
    4W4A / 8W8A settings); pass 16 for the weight-only W4A16 configuration
    of Tables 10/11 (activations stay FP, the MatMul runs on dequantized
    weights) or 8 for the mixed W4A8 ablation.
    """
    a_bits = qw.bits if act_bits is None else act_bits
    k_base = qw.w_int.shape[1]
    x_base, x_fp = x[:, :k_base], x[:, k_base:]
    if a_bits >= 16:
        w_deq = qw.w_int.astype(jnp.float32) * qw.scale_w[:, None]
        y = jnp.matmul(x_base.astype(jnp.float32), w_deq.T)
    else:
        qa = quantize_acts_ref(x_base, a_bits)
        acc = int_matmul_ref(qa.q, qw.w_int)
        y = dequantize_ref(
            acc, qa.scale, qa.zero, qw.scale_w, qw.w_reduced, a_bits
        )
    if x_fp.shape[1]:
        y = y + jnp.matmul(x_fp.astype(jnp.float32), qw.w_fp.T)
    if bias is not None:
        y = y + bias[None, :]
    return y


def quant_error_ref(x: jnp.ndarray, bits: int) -> jnp.ndarray:
    """Per-row squared reconstruction error — calibration diagnostics."""
    qa = quantize_acts_ref(x, bits)
    return jnp.sum((dequantize_acts_ref(qa, bits) - x) ** 2, axis=-1)
