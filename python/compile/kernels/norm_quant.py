"""Fused RMSNorm → outlier-split → quantize kernel (extension).

In the QUIK forward pass every quantized linear layer's input comes out of
a normalization (LLaMA blocks) — so the activation tensor is read from HBM
by the norm, written back, then read again by the quantization kernel.
Fusing the three stages removes one full HBM round-trip of the hidden
state, exactly the class of optimization §3.4 applies inside the quant
pipeline, extended one operator upstream (the same trick SmoothQuant uses
to hide its migration scale in the LayerNorm).

Per `(block_m, D)` VMEM-resident tile:

1. RMSNorm: ``x * rsqrt(mean(x²) + ε) * g`` (gain already permuted to the
   outlier-last order);
2. static split: trailing ``n_outlier`` columns out in FP;
3. per-token min/max + asymmetric quantization of the base block.

Numerics: identical to ``norm → permute → split_quantize`` composed (the
reference path in :func:`norm_split_quantize_ref`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .quant import DEFAULT_BLOCK_M, _pad_rows, _quant_block
from .ref import QuantizedActs, quantize_acts_ref


def _norm_quant_kernel(
    x_ref, g_ref, q_ref, fp_ref, scale_ref, zero_ref, *, bits: int, k_base: int, eps: float
):
    x = x_ref[...]
    ms = jnp.mean(x * x, axis=1, keepdims=True)
    xn = x * jax.lax.rsqrt(ms + eps) * g_ref[...][None, :]
    base = xn[:, :k_base]
    q, scale, zero = _quant_block(base, bits)
    q_ref[...] = q
    fp_ref[...] = xn[:, k_base:]
    scale_ref[...] = scale
    zero_ref[...] = zero


@functools.partial(
    jax.jit, static_argnames=("n_outlier", "bits", "block_m", "eps")
)
def norm_split_quantize(
    x: jnp.ndarray,
    gain: jnp.ndarray,
    n_outlier: int,
    bits: int,
    block_m: int = DEFAULT_BLOCK_M,
    eps: float = 1e-6,
) -> tuple[QuantizedActs, jnp.ndarray]:
    """Fused RMSNorm + outlier split + per-token quantization.

    Args:
      x: ``f32[M, D]`` **outlier-permuted** residual-stream activations
        (the permutation commutes with RMSNorm: the mean-square is
        order-invariant, so permuting before the norm is exact as long as
        ``gain`` is permuted identically).
      gain: ``f32[D]`` RMSNorm gain in the same permuted order.
      n_outlier: trailing FP16 outlier columns.
      bits: activation bit width.

    Returns:
      ``(QuantizedActs over the base block, f32[M, n_outlier] outliers)``.
    """
    if n_outlier == 0:
        # degenerate split: fuse norm+quant only
        from .quant import quantize_acts

        ms = jnp.mean(x * x, axis=1, keepdims=True)
        xn = x * jax.lax.rsqrt(ms + eps) * gain[None, :]
        return quantize_acts(xn, bits, block_m), xn[:, :0]
    xp, m = _pad_rows(x, block_m)
    mp, d = xp.shape
    k_base = d - n_outlier
    q, fp, scale, zero = pl.pallas_call(
        functools.partial(
            _norm_quant_kernel, bits=bits, k_base=k_base, eps=eps
        ),
        grid=(mp // block_m,),
        in_specs=[
            pl.BlockSpec((block_m, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_m, k_base), lambda i: (i, 0)),
            pl.BlockSpec((block_m, n_outlier), lambda i: (i, 0)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
            pl.BlockSpec((block_m,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((mp, k_base), jnp.int8),
            jax.ShapeDtypeStruct((mp, n_outlier), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
            jax.ShapeDtypeStruct((mp,), jnp.float32),
        ],
        interpret=True,
    )(xp, gain)
    return QuantizedActs(q=q[:m], scale=scale[:m], zero=zero[:m]), fp[:m]


def norm_split_quantize_ref(
    x: jnp.ndarray,
    gain: jnp.ndarray,
    n_outlier: int,
    bits: int,
    eps: float = 1e-6,
) -> tuple[QuantizedActs, jnp.ndarray]:
    """Unfused oracle: RMSNorm, then slice, then quantize."""
    ms = jnp.mean(x * x, axis=1, keepdims=True)
    xn = x * jax.lax.rsqrt(ms + eps) * gain[None, :]
    k_base = x.shape[1] - n_outlier
    return quantize_acts_ref(xn[:, :k_base], bits), xn[:, k_base:]
