"""Pallas integer matmul kernels with fused dequantization epilogue.

The paper's compute backbone is a CUTLASS INT4/INT8 tensor-core MatMul with
INT32 accumulation, plus a custom *epilogue* that applies the scale/zero
correction (paper Eq. 1) before the accumulator tile ever leaves registers
(§3.4 "Dequantization Epilogue").  The TPU/Pallas rethink targets the MXU:

* the grid is ``(M/bm, N/bn, K/bk)`` with the K axis innermost so the int32
  accumulator tile stays VMEM-resident across the whole reduction;
* ``jnp.dot(..., preferred_element_type=int32)`` maps onto the MXU systolic
  array (int8 operands — the INT4 values are int8-carried in interpret
  mode, packed as nibbles only in the storage format);
* the dequantization epilogue — and the accumulation of the FP outlier
  MatMul result — runs on the final K step, before the single HBM
  write-out: the exact analogue of CUTLASS's pre-commit register epilogue.

``int_matmul`` (no epilogue) + ``dequantize_acc`` reproduce the *unfused*
"version 2" pipeline of Figure 6; ``int_matmul_dequant`` is the fully fused
"version 3".
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from .ref import half_range

# MXU-shaped default tiles: 128×128 output tile, 128-deep reduction slab.
# At int8 this is 3 × 128×128 ≤ 64 KiB of VMEM per step — far under the
# ~16 MiB budget, leaving room for double buffering (see DESIGN.md §Perf).
DEFAULT_BLOCK_M = 128
DEFAULT_BLOCK_N = 128
DEFAULT_BLOCK_K = 128


def _pad2(x: jnp.ndarray, bm: int, bk: int) -> jnp.ndarray:
    pm = (-x.shape[0]) % bm
    pk = (-x.shape[1]) % bk
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    return x


def _pad1(x: jnp.ndarray, b: int) -> jnp.ndarray:
    p = (-x.shape[0]) % b
    if p:
        x = jnp.pad(x, ((0, p),))
    return x


def _blocks(m: int, n: int, k: int, bm: int, bn: int, bk: int):
    bm = min(bm, m) if m else bm
    bn = min(bn, n) if n else bn
    bk = min(bk, k) if k else bk
    return bm, bn, bk


def _int_mm_kernel(qx_ref, qw_ref, out_ref, acc_ref):
    """Plain INT×INT tiled matmul, int32 accumulation in VMEM scratch."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        qx_ref[...].astype(jnp.int32),
        qw_ref[...].astype(jnp.int32).T,
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _commit():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k"))
def int_matmul(
    qx: jnp.ndarray,
    qw: jnp.ndarray,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
) -> jnp.ndarray:
    """``qx[M,K] @ qw[N,K]^T`` with int32 accumulation (no epilogue).

    The CUTLASS-equivalent raw integer MatMul.  Zero padding on any axis is
    harmless: padded int8 operands contribute 0 to the accumulator.
    """
    m, k = qx.shape
    n = qw.shape[0]
    bm, bn, bk = _blocks(m, n, k, block_m, block_n, block_k)
    qxp, qwp = _pad2(qx, bm, bk), _pad2(qw, bn, bk)
    mp, kp = qxp.shape
    np_ = qwp.shape[0]
    out = pl.pallas_call(
        _int_mm_kernel,
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=True,
    )(qxp, qwp)
    return out[:m, :n]


def _dequant_kernel(
    acc_ref, sa_ref, za_ref, sw_ref, wr_ref, out_ref, *, bits: int
):
    """Standalone dequantization pass (v2 pipeline): int32 tile → f32 tile."""
    acc = acc_ref[...].astype(jnp.float32)
    sa = sa_ref[...]
    shift = za_ref[...] + half_range(bits) * sa
    out_ref[...] = acc * sa[:, None] * sw_ref[...][None, :] + shift[:, None] * wr_ref[...][None, :]


@functools.partial(jax.jit, static_argnames=("bits", "block_m", "block_n"))
def dequantize_acc(
    acc: jnp.ndarray,
    scale_act: jnp.ndarray,
    zero_act: jnp.ndarray,
    scale_w: jnp.ndarray,
    w_reduced: jnp.ndarray,
    bits: int,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
) -> jnp.ndarray:
    """Unfused dequantization kernel (Algorithm 1 ``Dequantization``).

    Reads the int32 accumulator back from HBM — exactly the round-trip the
    fused epilogue of :func:`int_matmul_dequant` eliminates.
    """
    m, n = acc.shape
    bm, bn, _ = _blocks(m, n, 1, block_m, block_n, 1)
    accp = _pad2(acc, bm, bn)
    sa, za = _pad1(scale_act, bm), _pad1(zero_act, bm)
    sw, wr = _pad1(scale_w, bn), _pad1(w_reduced, bn)
    mp, np_ = accp.shape
    out = pl.pallas_call(
        functools.partial(_dequant_kernel, bits=bits),
        grid=(mp // bm, np_ // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bm,), lambda i, j: (i,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
            pl.BlockSpec((bn,), lambda i, j: (j,)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        interpret=True,
    )(accp, sa, za, sw, wr)
    return out[:m, :n]


def _int_mm_dequant_kernel(
    qx_ref, qw_ref, sa_ref, za_ref, sw_ref, wr_ref, fp_ref,
    out_ref, acc_ref, *, bits: int,
):
    """Fused matmul + dequant epilogue + outlier-result accumulation (v3).

    The epilogue fires on the last K step while the int32 accumulator tile
    is still VMEM-resident; the FP outlier MatMul result (``fp_ref``) is
    accumulated in the same breath (Algorithm 1 line 8), so the output tile
    is written to HBM exactly once, fully dequantized.
    """
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(
        qx_ref[...].astype(jnp.int32),
        qw_ref[...].astype(jnp.int32).T,
        preferred_element_type=jnp.int32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _epilogue():
        acc = acc_ref[...].astype(jnp.float32)
        sa = sa_ref[...]
        shift = za_ref[...] + half_range(bits) * sa
        y = acc * sa[:, None] * sw_ref[...][None, :]
        y += shift[:, None] * wr_ref[...][None, :]
        out_ref[...] = y + fp_ref[...]


@functools.partial(
    jax.jit, static_argnames=("bits", "block_m", "block_n", "block_k")
)
def int_matmul_dequant(
    qx: jnp.ndarray,
    qw: jnp.ndarray,
    scale_act: jnp.ndarray,
    zero_act: jnp.ndarray,
    scale_w: jnp.ndarray,
    w_reduced: jnp.ndarray,
    result_fp: jnp.ndarray,
    bits: int,
    block_m: int = DEFAULT_BLOCK_M,
    block_n: int = DEFAULT_BLOCK_N,
    block_k: int = DEFAULT_BLOCK_K,
) -> jnp.ndarray:
    """Fully fused QUIK MatMul (Figure 6 "version 3").

    Args:
      qx: ``int8[M, K_base]`` quantized activations (INT``bits`` values).
      qw: ``int8[N, K_base]`` quantized weights.
      scale_act, zero_act: ``f32[M]`` per-token metadata.
      scale_w, w_reduced: ``f32[N]`` per-output weight metadata.
      result_fp: ``f32[M, N]`` result of the outlier FP MatMul, accumulated
        into the epilogue (pass zeros when there are no outliers).
      bits: activation/weight bit width (4 or 8).

    Returns:
      ``f32[M, N]`` dequantized output — Algorithm 1's ``dequantFP +
      resultFP`` computed with a single HBM write.
    """
    m, k = qx.shape
    n = qw.shape[0]
    bm, bn, bk = _blocks(m, n, k, block_m, block_n, block_k)
    qxp, qwp = _pad2(qx, bm, bk), _pad2(qw, bn, bk)
    sa, za = _pad1(scale_act, bm), _pad1(zero_act, bm)
    sw, wr = _pad1(scale_w, bn), _pad1(w_reduced, bn)
    fpp = _pad2(result_fp, bm, bn)
    mp, kp = qxp.shape
    np_ = qwp.shape[0]
    out = pl.pallas_call(
        functools.partial(_int_mm_dequant_kernel, bits=bits),
        grid=(mp // bm, np_ // bn, kp // bk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn, bk), lambda i, j, kk: (j, kk)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bm,), lambda i, j, kk: (i,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bn,), lambda i, j, kk: (j,)),
            pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((bm, bn), jnp.int32)],
        interpret=True,
    )(qxp, qwp, sa, za, sw, wr, fpp)
    return out[:m, :n]
