"""The complete QUIK linear layer — Algorithm 1 end to end.

``quik_linear`` composes the L1 kernels into the paper's mixed-precision
forward pass for one linear layer::

    x (outlier-permuted) ──split──▶ x_base ──quant──▶ INT MatMul ─┐
                          └───────▶ x_fp  ──FP MatMul─────────────┤
                                                  dequant epilogue ▼
                                                        y = dequantFP + resultFP

Three ``version`` settings reproduce the Figure 6 kernel-fusion ablation:

=======  =============================  ==============================
version  quantization                   dequantization
=======  =============================  ==============================
1        unfused (5 logical passes)     unfused (extra int32 round-trip)
2        fused split+quant kernel       unfused
3        fused split+quant kernel       fused MatMul epilogue
=======  =============================  ==============================

All three are numerically identical (checked in
``python/tests/test_quik_linear.py``); they differ only in memory traffic,
which is what the device model (``rust/src/devicemodel``) charges for.

This module is what L2 (``compile.model``) calls for every linear layer, so
the whole pipeline lowers into the model's single HLO artifact.
"""

from __future__ import annotations

import jax.numpy as jnp

from . import matmul, quant
from .ref import QuantizedWeights


def _fp_matmul(x_fp: jnp.ndarray, w_fp: jnp.ndarray, m: int, n: int) -> jnp.ndarray:
    """Outlier (full-precision) MatMul; zeros when the layer has no outliers."""
    if x_fp.shape[1] == 0:
        return jnp.zeros((m, n), jnp.float32)
    return jnp.matmul(x_fp.astype(jnp.float32), w_fp.T)


def quik_linear(
    x: jnp.ndarray,
    qw: QuantizedWeights,
    bias: jnp.ndarray | None = None,
    version: int = 3,
    block_m: int | None = None,
    act_bits: int | None = None,
) -> jnp.ndarray:
    """QUIK mixed-precision linear layer ``y ≈ x @ W^T + b``.

    Args:
      x: ``f32[M, K]`` activations, column-permuted so outlier features are
        the trailing ``qw.w_fp.shape[1]`` columns (the permutation is fixed
        offline by calibration — see ``compile.quik.outliers``).
      qw: offline-quantized weight package (GPTQ or RTN).
      bias: optional ``f32[N]``.
      version: fusion level 1/2/3 (see module docstring).
      block_m: override the quantization token-tile height.
      act_bits: activation bit width; defaults to ``qw.bits``.  16 selects
        the weight-only path (FP activations × dequantized weights — the
        W4A16 rows of Tables 10/11).

    Returns:
      ``f32[M, N]``.
    """
    if version not in (1, 2, 3):
        raise ValueError(f"version must be 1, 2 or 3, got {version}")
    a_bits = qw.bits if act_bits is None else act_bits
    n_outlier = qw.w_fp.shape[1]
    m = x.shape[0]
    n = qw.w_int.shape[0]
    bm = block_m or quant.DEFAULT_BLOCK_M
    k_base = qw.w_int.shape[1]

    if a_bits >= 16:
        # Weight-only configuration: no activation quantization at all; the
        # MatMul runs in FP on dequantized weights (memory-bound-only gains).
        w_deq = qw.w_int.astype(jnp.float32) * qw.scale_w[:, None]
        y = jnp.matmul(x[:, :k_base].astype(jnp.float32), w_deq.T)
        y = y + _fp_matmul(x[:, k_base:], qw.w_fp, m, n)
        if bias is not None:
            y = y + bias[None, :]
        return y

    # --- split + quantize ---------------------------------------------
    if version == 1:
        qa, x_fp = quant.split_quantize_v1(x, n_outlier, a_bits)
    else:
        qa, x_fp = quant.split_quantize(x, n_outlier, a_bits, block_m=bm)

    # --- FP outlier MatMul (always a separate MXU call, as in the paper
    # where it is a separate cuBLAS/CUTLASS FP16 GEMM) -------------------
    result_fp = _fp_matmul(x_fp, qw.w_fp, m, n)

    # --- INT MatMul + dequantization -----------------------------------
    if version == 3:
        y = matmul.int_matmul_dequant(
            qa.q, qw.w_int, qa.scale, qa.zero, qw.scale_w, qw.w_reduced,
            result_fp, a_bits,
        )
    else:
        acc = matmul.int_matmul(qa.q, qw.w_int)
        y = matmul.dequantize_acc(
            acc, qa.scale, qa.zero, qw.scale_w, qw.w_reduced, a_bits
        )
        y = y + result_fp

    if bias is not None:
        y = y + bias[None, :]
    return y
