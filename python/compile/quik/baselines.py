"""Baseline quantization schemes the paper compares against (Tables 1-4).

* **RTN (round-to-nearest)** — plain symmetric per-output weight
  quantization with no error compensation; with ``n_outlier = 0`` this is
  the "0 Outliers" row of Table 10 that collapses to 10k+ perplexity.
* **SmoothQuant** (Xiao et al. 2022) — migrates activation outlier
  magnitude into the weights with a per-feature scale
  ``s_k = max|X_k|^α / max|W_k|^(1-α)`` before quantizing both sides.
  Close to lossless at 8 bits (Table 4) but breaks down at 4 bits
  (Table 1: 1.8e4 perplexity on OPT-6.7B).
* **GPTQ weight-only (W4A16)** — GPTQ weights, FP activations; the
  memory-bound-only baseline of Tables 10/11.

All emit the shared :class:`~compile.kernels.ref.QuantizedWeights`
container so the same model forward / eval harness runs every scheme.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from ..kernels.ref import QuantizedWeights, weight_qmax


def rtn_quantize(
    w: np.ndarray, bits: int, n_outlier: int = 0
) -> QuantizedWeights:
    """Round-to-nearest symmetric per-output quantization (no Hessian)."""
    w = np.asarray(w, np.float32)
    k_base = w.shape[1] - n_outlier
    base, w_fp = w[:, :k_base], w[:, k_base:]
    qmax = weight_qmax(bits)
    scale = np.maximum(np.max(np.abs(base), axis=1), 1e-8) / qmax
    w_int = np.clip(np.round(base / scale[:, None]), -qmax, qmax).astype(np.int8)
    w_reduced = scale * w_int.astype(np.float32).sum(axis=1)
    return QuantizedWeights(
        w_int=jnp.asarray(w_int),
        w_fp=jnp.asarray(w_fp),
        scale_w=jnp.asarray(scale),
        w_reduced=jnp.asarray(w_reduced),
        bits=bits,
    )


@dataclass(frozen=True)
class SmoothQuantResult:
    """SmoothQuant package: quantized scaled weights + the migration scale.

    At runtime the activations must be divided by ``smooth_scale``
    feature-wise before the quantized MatMul (in the real system this
    divide is fused into the preceding LayerNorm — which is exactly why
    SmoothQuant cannot handle Falcon-7B's shared layer norm, §4.1).
    """

    qw: QuantizedWeights
    smooth_scale: np.ndarray  # f32[K]


def smoothquant_scales(
    act_linf: np.ndarray, w: np.ndarray, alpha: float
) -> np.ndarray:
    """Per-input-feature migration scale ``s_k = max|X_k|^α / max|W_k|^(1-α)``."""
    act_linf = np.maximum(np.asarray(act_linf, np.float32), 1e-5)
    w_linf = np.maximum(np.max(np.abs(w), axis=0), 1e-5)
    s = act_linf**alpha / w_linf ** (1.0 - alpha)
    return np.maximum(s, 1e-5).astype(np.float32)


def smoothquant_quantize(
    w: np.ndarray,
    act_linf: np.ndarray,
    bits: int,
    alpha: float = 0.5,
) -> SmoothQuantResult:
    """SmoothQuant: migrate difficulty, then RTN-quantize ``W · diag(s)``.

    No outlier columns — SmoothQuant's whole premise is that migration makes
    them unnecessary (true at 8 bits, false at 4: Tables 1 & 4).
    """
    w = np.asarray(w, np.float32)
    s = smoothquant_scales(act_linf, w, alpha)
    qw = rtn_quantize(w * s[None, :], bits, n_outlier=0)
    return SmoothQuantResult(qw=qw, smooth_scale=s)


def smooth_activations(x: np.ndarray, s: np.ndarray) -> np.ndarray:
    """Apply the inverse migration ``X / s`` (runtime side of SmoothQuant)."""
    return np.asarray(x, np.float32) / s[None, :]
