"""Outlier feature selection and column permutation (paper §3.2, Fig. 4).

Activation matrices in trained LLMs contain *outlier features*: a small set
of columns whose magnitudes run up to 100× larger than the rest.  Following
SmoothQuant's observation that these features are **fixed per layer across
datasets**, QUIK extracts their indices *offline* from a small calibration
set and permutes them to the end of the feature axis, so the runtime split
is a static slice (no on-the-fly outlier detection à la LLM.int8()).

This module computes, per linear layer:

* the ℓ∞ norm (max |x|) of every input feature over the calibration set —
  the outlier score used for selection;
* per-feature variance — the sensitivity diagnostic behind Figure 10 and
  the 8-bit down-projection policy;
* the permutation placing the top-``n_outlier`` features last, plus its
  inverse (needed to permute weight columns and, at runtime, incoming
  activations).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np


@dataclass(frozen=True)
class CalibStats:
    """Per-input-feature statistics of one linear layer's calibration input."""

    linf: np.ndarray      # f32[K] max |x| per feature (outlier score)
    variance: np.ndarray  # f32[K] per-feature variance (Fig. 10 diagnostic)
    mean_sq: np.ndarray   # f32[K] E[x^2] per feature (Hessian diagonal / #rows)

    @property
    def k(self) -> int:
        return self.linf.shape[0]


def collect_stats(x: np.ndarray) -> CalibStats:
    """Compute calibration statistics from ``x`` of shape ``[tokens, K]``."""
    x = np.asarray(x, np.float32)
    return CalibStats(
        linf=np.max(np.abs(x), axis=0),
        variance=np.var(x, axis=0),
        mean_sq=np.mean(x * x, axis=0),
    )


def merge_stats(stats: list[CalibStats]) -> CalibStats:
    """Merge statistics from multiple calibration batches (equal weights)."""
    if not stats:
        raise ValueError("no calibration statistics to merge")
    return CalibStats(
        linf=np.max([s.linf for s in stats], axis=0),
        variance=np.mean([s.variance for s in stats], axis=0),
        mean_sq=np.mean([s.mean_sq for s in stats], axis=0),
    )


def select_outliers(stats: CalibStats, n_outlier: int) -> np.ndarray:
    """Indices of the ``n_outlier`` features with the largest ℓ∞ norm.

    Returned sorted ascending (a stable layout for the permutation); the
    paper selects by ℓ∞ norm following SmoothQuant / LLM.int8().
    """
    if n_outlier < 0 or n_outlier > stats.k:
        raise ValueError(f"n_outlier={n_outlier} out of range for K={stats.k}")
    if n_outlier == 0:
        return np.empty(0, np.int64)
    idx = np.argpartition(-stats.linf, n_outlier - 1)[:n_outlier]
    return np.sort(idx)


def outlier_permutation(k: int, outlier_idx: np.ndarray) -> np.ndarray:
    """Permutation ``perm`` moving ``outlier_idx`` to the *end* of ``0..K``.

    ``x_permuted = x[:, perm]``; base features keep their relative order,
    outlier features keep theirs.  This is the reordering of Figure 4 that
    lets GPTQ accumulate quantization error into the trailing FP16 columns.
    """
    outlier_idx = np.asarray(outlier_idx, np.int64)
    mask = np.zeros(k, bool)
    mask[outlier_idx] = True
    base = np.nonzero(~mask)[0]
    return np.concatenate([base, outlier_idx])


def inverse_permutation(perm: np.ndarray) -> np.ndarray:
    """Inverse of :func:`outlier_permutation` (restores original order)."""
    inv = np.empty_like(perm)
    inv[perm] = np.arange(perm.shape[0])
    return inv


def permute_hessian(h: np.ndarray, perm: np.ndarray) -> np.ndarray:
    """Re-order a Hessian ``H = X^T X`` consistently with a column perm."""
    return h[np.ix_(perm, perm)]


def max_scale(stats: CalibStats, bits: int, n_outlier: int) -> float:
    """Max per-token quantization scale proxy for the zero-outlier rule.

    Table 5 drops outliers from layers whose "maximum of scale" falls below
    a threshold ``T``.  The offline proxy is the widest calibration range of
    the base block divided by the quantization levels: layers whose inputs
    are tame (small scale) don't need FP16 outliers at all.
    """
    perm = outlier_permutation(stats.k, select_outliers(stats, n_outlier))
    base_linf = stats.linf[perm[: stats.k - n_outlier]] if n_outlier else stats.linf
    # Asymmetric per-token range is ≤ 2·max|x|; scale = range / (2^b - 1).
    return float(2.0 * np.max(base_linf) / ((1 << bits) - 1))
