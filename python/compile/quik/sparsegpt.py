"""SparseGPT extended with QUIK outliers: joint 2:4 sparsity + quantization.

Paper §4.3.2: naively sparsifying an already-quantized model (or vice
versa) wrecks accuracy; instead the SparseGPT algorithm (Frantar &
Alistarh 2023) is extended to (a) jointly decide the 2:4 mask and the
quantized values with shared second-order error compensation, and (b) keep
the QUIK outlier feature columns dense *and* in FP16.

The 2:4 pattern (two of every four consecutive weights zero) is what
NVIDIA sparse tensor cores accelerate; here it is enforced along the input
(column) dimension of the base block.  Mask selection per group of 4
columns uses the SparseGPT saliency ``w² / [H^{-1}]_jj²``; pruned weights
propagate their full value as error, surviving weights are quantized (or
kept FP for the sparse-only configuration) and propagate their rounding
error — all through the same inverse-Hessian Cholesky updates as GPTQ.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from ..kernels.ref import QuantizedWeights, weight_qmax
from .gptq import _inv_hessian_cholesky


@dataclass(frozen=True)
class SparseGPTConfig:
    """Joint sparsification + quantization hyper-parameters."""

    bits: int | None = 4      # None → sparsify only, keep weights FP
    n_outlier: int = 0        # trailing dense-FP16 outlier columns
    prune_n: int = 2          # zeros per group
    prune_m: int = 4          # group size  (2:4 — the hardware pattern)
    damp: float = 0.01
    block_size: int = 128


def sparsegpt_quantize(
    w: np.ndarray,
    hessian: np.ndarray,
    cfg: SparseGPTConfig,
) -> tuple[QuantizedWeights, np.ndarray, float]:
    """Jointly 2:4-sparsify and quantize ``w`` (outlier columns dense/FP).

    Args:
      w: ``f32[N, K]`` column-permuted weights (outliers last).
      hessian: ``[K, K]`` permuted calibration Hessian.
      cfg: see :class:`SparseGPTConfig`.

    Returns:
      ``(QuantizedWeights, mask, proxy_error)`` — ``mask`` is the boolean
      keep-mask over the base block (``True`` = kept), guaranteed to satisfy
      the ``prune_n:prune_m`` pattern on every full group; ``w_int`` is 0 at
      pruned positions so the packed format stays valid.
    """
    w = np.array(w, np.float64, copy=True)
    n, k = w.shape
    k_base = k - cfg.n_outlier
    if k_base <= 0:
        raise ValueError("all columns marked outlier — nothing to sparsify")

    u = _inv_hessian_cholesky(hessian, cfg.damp)
    bits = cfg.bits
    qmax = weight_qmax(bits) if bits is not None else 0

    # Scale from the base block before any update (symmetric per-output).
    if bits is not None:
        scale = np.maximum(np.max(np.abs(w[:, :k_base]), axis=1), 1e-8) / qmax
    else:
        scale = np.ones(n)

    keep = np.ones((n, k_base), bool)
    w_q = np.zeros((n, k_base), np.float64)   # dequantized kept values
    w_int = np.zeros((n, k_base), np.int8)
    proxy_err = 0.0

    for start in range(0, k, cfg.block_size):
        end = min(start + cfg.block_size, k)
        w_blk = w[:, start:end]
        err_blk = np.zeros((n, end - start), np.float64)
        mask_blk: np.ndarray | None = None
        group_start = -1
        for j in range(start, end):
            jj = j - start
            col = w_blk[:, jj]
            if j < k_base:
                # (Re)compute the prune mask at each group boundary, using
                # the *updated* weights — SparseGPT's adaptive mask choice.
                if j % cfg.prune_m == 0 and j + cfg.prune_m <= k_base:
                    group_start = j
                    g = w_blk[:, jj : jj + cfg.prune_m]
                    d = np.diag(u)[j : j + cfg.prune_m]
                    saliency = (g / d[None, :]) ** 2
                    order = np.argsort(saliency, axis=1)
                    gmask = np.ones((n, cfg.prune_m), bool)
                    rows = np.arange(n)[:, None]
                    gmask[rows, order[:, : cfg.prune_n]] = False
                    keep[:, j : j + cfg.prune_m] = gmask
                    mask_blk = gmask
                in_group = (
                    mask_blk is not None
                    and group_start >= 0
                    and group_start <= j < group_start + cfg.prune_m
                )
                kept = keep[:, j] if in_group else np.ones(n, bool)
                keep[:, j] = kept
                if bits is not None:
                    q = np.clip(np.round(col / scale), -qmax, qmax)
                    dq = np.where(kept, q * scale, 0.0)
                    w_int[:, j] = np.where(kept, q, 0).astype(np.int8)
                else:
                    dq = np.where(kept, col, 0.0)
                w_q[:, j] = dq
            else:
                dq = col  # dense FP outlier column
            err = (col - dq) / u[j, j]
            proxy_err += float(np.sum(err * err))
            if jj + 1 < end - start:
                w_blk[:, jj + 1 :] -= np.outer(err, u[j, j + 1 : end])
            err_blk[:, jj] = err
        if end < k:
            w[:, end:] -= err_blk @ u[start:end, end:]

    w_fp = w[:, k_base:].astype(np.float32)
    scale32 = scale.astype(np.float32)
    if bits is None:
        # Sparse-FP configuration: encode kept FP values through an INT8
        # container is not possible losslessly; callers use `w_q` instead.
        bits_out = 16
        w_reduced = np.zeros(n, np.float32)
        w_int_out = w_int
    else:
        bits_out = bits
        w_reduced = scale32 * w_int.astype(np.float32).sum(axis=1)
        w_int_out = w_int
    qw = QuantizedWeights(
        w_int=jnp.asarray(w_int_out),
        w_fp=jnp.asarray(w_fp),
        scale_w=jnp.asarray(scale32),
        w_reduced=jnp.asarray(w_reduced),
        bits=bits_out,
    )
    return qw, keep, proxy_err


def check_24_pattern(mask: np.ndarray, prune_n: int = 2, prune_m: int = 4) -> bool:
    """Verify every full ``prune_m`` group keeps exactly ``m - n`` weights."""
    n, k = mask.shape
    full = (k // prune_m) * prune_m
    if full == 0:
        return True
    groups = mask[:, :full].reshape(n, -1, prune_m)
    return bool(np.all(groups.sum(axis=2) == prune_m - prune_n))


def sparsity_ratio(mask: np.ndarray) -> float:
    """Fraction of pruned weights in the base block."""
    return float(1.0 - mask.mean())
