"""GPTQ weight quantization with outlier-aware column reordering (§3.1-3.2).

GPTQ iterates over weight columns; each column is quantized in one shot and
the *remaining* (right-hand) columns are updated with second-order
information — the inverse-Hessian Cholesky factor — to compensate the error
just introduced.  Error therefore accumulates toward the last columns.

QUIK's twist (Figure 4): permute the activation-outlier columns to the end
*before* running GPTQ.  Then

1. the "difficult" outlier columns are never quantized at all (they stay
   FP16 at runtime),
2. the error accumulated by GPTQ lands exactly in those FP16 columns, and
3. weight outliers no longer inflate the 4-bit quantization scale.

The implementation is from scratch in float64 numpy (Cholesky-based, with
dampening and lazy block updates exactly as in Frantar et al. 2022) and
emits the same :class:`~compile.kernels.ref.QuantizedWeights` container the
Pallas kernels consume.
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from ..kernels.ref import QuantizedWeights, weight_qmax
from . import clipping


@dataclass(frozen=True)
class GPTQConfig:
    """Hyper-parameters of the GPTQ pass (paper defaults)."""

    bits: int = 4
    n_outlier: int = 0        # trailing FP16 columns (already permuted last)
    damp: float = 0.01        # dampening fraction of mean Hessian diagonal
    block_size: int = 128     # lazy-update block width
    clip: bool = False        # linear-search weight clipping (§3.2)


def hessian_from_calib(x: np.ndarray) -> np.ndarray:
    """Layer Hessian ``H = 2 X^T X`` from calibration inputs ``[tokens, K]``.

    The constant factor is irrelevant to GPTQ (it cancels in the update);
    we keep the conventional ``2`` for parity with the reference code.
    """
    x = np.asarray(x, np.float64)
    return 2.0 * (x.T @ x)


def _inv_hessian_cholesky(h: np.ndarray, damp: float) -> np.ndarray:
    """Upper Cholesky factor of ``H^{-1}`` with dead-column handling."""
    h = np.array(h, np.float64, copy=True)
    k = h.shape[0]
    dead = np.diag(h) == 0
    h[dead, dead] = 1.0
    mean_diag = float(np.mean(np.diag(h)))
    h[np.arange(k), np.arange(k)] += damp * mean_diag
    hinv = np.linalg.inv(h)
    # Upper-triangular Cholesky factor U with H^{-1} = U^T U — the
    # orientation GPTQ's column updates consume (rows of U index the
    # already-quantized column, columns the ones still to fix up).
    return np.linalg.cholesky(hinv).T


def gptq_quantize(
    w: np.ndarray,
    hessian: np.ndarray,
    cfg: GPTQConfig,
) -> tuple[QuantizedWeights, float]:
    """Quantize ``w`` with GPTQ; outlier columns absorb the residual error.

    Args:
      w: ``f32[N, K]`` weight matrix, **column-permuted** so the trailing
        ``cfg.n_outlier`` input features are the activation outliers.
      hessian: ``[K, K]`` calibration Hessian in the *same permuted order*
        (use :func:`~compile.quik.outliers.permute_hessian`).
      cfg: GPTQ hyper-parameters.

    Returns:
      ``(QuantizedWeights, proxy_error)`` where ``proxy_error`` is the
      Hessian-weighted squared error ``Σ err^2 / U_jj^2`` — the quantity
      GPTQ minimizes, useful for ablation diagnostics.
    """
    w = np.array(w, np.float64, copy=True)
    n, k = w.shape
    k_base = k - cfg.n_outlier
    if k_base <= 0:
        raise ValueError("all columns marked outlier — nothing to quantize")
    if hessian.shape != (k, k):
        raise ValueError(f"hessian shape {hessian.shape} != ({k}, {k})")

    u = _inv_hessian_cholesky(hessian, cfg.damp)
    qmax = weight_qmax(cfg.bits)

    # Per-output symmetric scale over the BASE columns only (outliers are
    # excluded, removing weight outliers from the scale — §3.2), optionally
    # clipped by linear search weighted by the Hessian diagonal.
    base = w[:, :k_base].astype(np.float32)
    if cfg.clip:
        h_diag = np.asarray(np.diag(hessian)[:k_base], np.float32)
        scale = clipping.search_clip_scale(base, cfg.bits, h_diag=h_diag)
    else:
        scale = np.maximum(np.max(np.abs(base), axis=1), 1e-8) / qmax
    scale = scale.astype(np.float64)

    w_int = np.zeros((n, k_base), np.int8)
    proxy_err = 0.0

    for start in range(0, k, cfg.block_size):
        end = min(start + cfg.block_size, k)
        w_blk = w[:, start:end]
        err_blk = np.zeros((n, end - start), np.float64)
        for j in range(start, end):
            jj = j - start
            col = w_blk[:, jj]
            if j < k_base:
                q = np.clip(np.round(col / scale), -qmax, qmax)
                w_int[:, j] = q.astype(np.int8)
                dq = q * scale
            else:
                # Outlier column: kept FP, no quantization error introduced.
                dq = col
            err = (col - dq) / u[j, j]
            proxy_err += float(np.sum(err * err))
            # In-block eager update of the remaining columns.
            if jj + 1 < end - start:
                w_blk[:, jj + 1 :] -= np.outer(err, u[j, j + 1 : end])
            err_blk[:, jj] = err
        # Lazy update of everything right of the block.
        if end < k:
            w[:, end:] -= err_blk @ u[start:end, end:]

    w_fp = w[:, k_base:].astype(np.float32)
    scale32 = scale.astype(np.float32)
    w_reduced = scale32 * w_int.astype(np.float32).sum(axis=1)
    qw = QuantizedWeights(
        w_int=jnp.asarray(w_int),
        w_fp=jnp.asarray(w_fp),
        scale_w=jnp.asarray(scale32),
        w_reduced=jnp.asarray(w_reduced),
        bits=cfg.bits,
    )
    return qw, proxy_err


def dequantized_weight(qw: QuantizedWeights) -> np.ndarray:
    """Reconstruct the effective ``[N, K]`` FP weight (base dequant + FP)."""
    base = np.asarray(qw.w_int, np.float32) * np.asarray(qw.scale_w)[:, None]
    return np.concatenate([base, np.asarray(qw.w_fp)], axis=1)
