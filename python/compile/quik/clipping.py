"""Weight clipping via linear search (paper §3.2 "Weight Clipping").

Symmetric RTN/GPTQ weight quantization uses ``scale = max|w| / qmax``; a
single large weight therefore inflates the scale and wastes quantization
levels on the tail.  Clipping trims the distribution first: we search over
shrink factors ``c ∈ (0, 1]`` applied to the scale and keep the one that
minimizes the squared reconstruction error — the paper's "linear search
over the clipping thresholds ... over the squared error".

This is the cheap heuristic alternative to learned clipping (PACT/LSQ/
OmniQuant); Table 11 shows it is worth ~0.1-0.2 perplexity on LLaMA-2.
"""

from __future__ import annotations

import numpy as np

from ..kernels.ref import weight_qmax

# Paper-style grid: 40 shrink factors from 1.0 down to ~0.3 of max|w|.
DEFAULT_GRID = np.linspace(1.0, 0.3, 40)


def quantize_rows_symmetric(
    w: np.ndarray, bits: int, scale: np.ndarray
) -> np.ndarray:
    """Round-to-nearest symmetric quantization with a given per-row scale."""
    qmax = weight_qmax(bits)
    q = np.clip(np.round(w / scale[:, None]), -qmax, qmax)
    return q


def search_clip_scale(
    w: np.ndarray,
    bits: int,
    grid: np.ndarray = DEFAULT_GRID,
    h_diag: np.ndarray | None = None,
) -> np.ndarray:
    """Per-row clipped quantization scale minimizing squared error.

    Args:
      w: ``f32[N, K]`` weight rows (base columns only — outliers excluded,
        which the paper notes also removes weight outliers from the scale).
      bits: weight bit width.
      grid: candidate shrink factors over ``max|w|``.
      h_diag: optional ``f32[K]`` Hessian diagonal (``E[x_k^2]``); when
        provided the error is input-weighted — the squared error *proxy of
        the layer output*, which is what GPTQ ultimately cares about.

    Returns:
      ``f32[N]`` per-row scales (already shrunk; feed straight to GPTQ/RTN).
    """
    w = np.asarray(w, np.float32)
    n = w.shape[0]
    qmax = weight_qmax(bits)
    base = np.maximum(np.max(np.abs(w), axis=1), 1e-8) / qmax  # unclipped
    weight = h_diag[None, :] if h_diag is not None else 1.0

    best_err = np.full(n, np.inf, np.float32)
    best_scale = base.copy()
    for c in grid:
        scale = base * c
        q = quantize_rows_symmetric(w, bits, scale)
        err = np.sum(weight * (q * scale[:, None] - w) ** 2, axis=1)
        better = err < best_err
        best_err = np.where(better, err, best_err)
        best_scale = np.where(better, scale, best_scale)
    return best_scale


def clip_error(w: np.ndarray, bits: int, scale: np.ndarray) -> float:
    """Total squared reconstruction error for a given scale (diagnostics)."""
    q = quantize_rows_symmetric(w, bits, scale)
    return float(np.sum((q * scale[:, None] - w) ** 2))
