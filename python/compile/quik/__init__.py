"""Offline QUIK calibration and quantization algorithms (build-time only).

Modules:
  outliers    ℓ∞-norm outlier feature selection + column permutation (Fig. 4)
  clipping    linear-search weight clipping over squared error (§3.2)
  gptq        GPTQ with outlier-aware column reordering (§3.1-3.2)
  policy      per-layer precision policy: 8-bit down-proj, zero-outlier
              thresholds, outlier-count scaling (§3.2, §4.3.1, Table 5)
  sparsegpt   SparseGPT extended with outlier columns: joint 2:4 + INT
              quantization (§4.3.2)
  baselines   RTN W4A4, SmoothQuant, GPTQ weight-only — comparison schemes
  quantize    model-level driver tying policy + calibration + GPTQ together
"""

from . import baselines, clipping, gptq, outliers, policy, quantize, sparsegpt  # noqa: F401
