"""Layer- and model-level quantization driver.

Ties the calibration pipeline together for one linear layer::

    calib acts ─▶ stats ─▶ outlier indices ─▶ permutation
                                   │
    weights ──▶ permute ──▶ Hessian (permuted) ──▶ GPTQ / RTN / SparseGPT
                                   │
                          QuantizedLinear  (consumed by L2 model + AOT)

``QuantizedLinear`` is scheme-agnostic: QUIK (GPTQ + outliers), RTN,
SmoothQuant and SparseGPT all produce one, and the same forward is used for
perplexity evals and for HLO export, so every accuracy table runs through
identical model code.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Literal

import numpy as np
import jax.numpy as jnp

from ..kernels import quik_linear as quik_linear_mod
from ..kernels.ref import QuantizedWeights, quik_linear_ref
from . import baselines, gptq, outliers, sparsegpt
from .policy import LayerPlan

Scheme = Literal["quik", "rtn", "smoothquant", "gptq_wonly", "sparse_quik", "fp16"]


@dataclass
class QuantizedLinear:
    """One linear layer ready for quantized inference.

    ``perm`` maps original feature order → permuted (outliers last); the
    runtime applies ``x[:, perm]`` before the QUIK kernel.  For FP16 layers
    everything except ``w`` / ``bias`` is ``None``.
    """

    plan: LayerPlan
    scheme: Scheme
    qw: QuantizedWeights | None          # None for fp16
    perm: np.ndarray | None              # int64[K]
    bias: jnp.ndarray | None
    w_fp16: jnp.ndarray | None = None    # retained only for fp16 scheme
    smooth_scale: np.ndarray | None = None  # SmoothQuant migration scale
    sparse_mask: np.ndarray | None = None   # 2:4 keep-mask (diagnostics)

    @property
    def out_features(self) -> int:
        if self.qw is not None:
            return int(self.qw.w_int.shape[0])
        return int(self.w_fp16.shape[0])

    @property
    def in_features(self) -> int:
        if self.qw is not None:
            return int(self.qw.w_int.shape[1] + self.qw.w_fp.shape[1])
        return int(self.w_fp16.shape[1])

    def __call__(self, x: jnp.ndarray, use_kernels: bool = False) -> jnp.ndarray:
        """Forward ``[M, K] → [M, N]``.

        ``use_kernels=True`` routes through the Pallas kernels (the path
        that lowers into the AOT artifact); ``False`` uses the jnp oracle —
        numerically identical, much faster under interpret-mode-free eval.
        """
        if self.scheme == "fp16":
            y = jnp.matmul(x, self.w_fp16.T)
            return y + self.bias[None, :] if self.bias is not None else y
        if self.smooth_scale is not None:
            x = x / jnp.asarray(self.smooth_scale)[None, :]
        if self.perm is not None:
            x = x[:, jnp.asarray(self.perm)]
        act_bits = self.plan.act_bits
        if use_kernels:
            return quik_linear_mod.quik_linear(
                x, self.qw, self.bias, version=3, act_bits=act_bits
            )
        return quik_linear_ref(x, self.qw, self.bias, act_bits=act_bits)


def quantize_linear(
    w: np.ndarray,
    calib_x: np.ndarray,
    plan: LayerPlan,
    scheme: Scheme = "quik",
    bias: np.ndarray | None = None,
    clip: bool = True,
    alpha: float = 0.5,
    damp: float = 0.01,
) -> QuantizedLinear:
    """Quantize one linear layer from its weight and calibration inputs.

    Args:
      w: ``f32[N, K]`` original (unpermuted) weight.
      calib_x: ``f32[tokens, K]`` calibration activations for this layer.
      plan: resolved precision plan (bits / outliers / sparsity).
      scheme: quantization algorithm (see module docstring).
      bias: optional ``f32[N]``.
      clip: enable linear-search weight clipping for the QUIK scheme.
      alpha: SmoothQuant migration strength.
      damp: GPTQ/SparseGPT Hessian dampening.
    """
    w = np.asarray(w, np.float32)
    bias_j = jnp.asarray(bias) if bias is not None else None

    if scheme == "fp16" or not plan.is_quantized:
        return QuantizedLinear(
            plan=plan, scheme="fp16", qw=None, perm=None,
            bias=bias_j, w_fp16=jnp.asarray(w),
        )

    stats = outliers.collect_stats(calib_x)
    n_out = min(plan.n_outlier, w.shape[1] - 1)

    if scheme == "smoothquant":
        res = baselines.smoothquant_quantize(
            w, stats.linf, plan.weight_bits, alpha=alpha
        )
        return QuantizedLinear(
            plan=plan, scheme=scheme, qw=res.qw, perm=None,
            bias=bias_j, smooth_scale=res.smooth_scale,
        )

    idx = outliers.select_outliers(stats, n_out)
    perm = outliers.outlier_permutation(w.shape[1], idx)
    w_p = w[:, perm]

    if scheme == "rtn":
        qw = baselines.rtn_quantize(w_p, plan.weight_bits, n_out)
        return QuantizedLinear(plan=plan, scheme=scheme, qw=qw, perm=perm, bias=bias_j)

    h = gptq.hessian_from_calib(np.asarray(calib_x)[:, perm])

    if scheme == "sparse_quik":
        cfg = sparsegpt.SparseGPTConfig(
            bits=plan.weight_bits, n_outlier=n_out, damp=damp
        )
        qw, mask, _ = sparsegpt.sparsegpt_quantize(w_p, h, cfg)
        return QuantizedLinear(
            plan=plan, scheme=scheme, qw=qw, perm=perm, bias=bias_j,
            sparse_mask=mask,
        )

    # "quik" and "gptq_wonly" share the GPTQ pass; they differ only in the
    # activation bits recorded in the plan (16 for weight-only).
    cfg = gptq.GPTQConfig(
        bits=plan.weight_bits, n_outlier=n_out, damp=damp, clip=clip
    )
    qw, _ = gptq.gptq_quantize(w_p, h, cfg)
    return QuantizedLinear(plan=plan, scheme=scheme, qw=qw, perm=perm, bias=bias_j)
