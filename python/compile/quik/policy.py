"""Per-layer precision policy (paper §3.2, §4.3.1, Tables 5/7/8).

QUIK is sensitivity-aware: most linear layers run 4W4A with a fixed outlier
budget, but layers whose inputs have pathological statistics get special
treatment —

* **Down-projection / FC2** layers (LLaMA's ``down_proj``, Falcon's
  ``fc2``): the SwiGLU/GeLU Hadamard-product input has much larger variance
  (Figure 10), so these layers are quantized to **8 bits** and their
  outlier count is scaled up proportionally to the input width (≈3.5×,
  Table 8's 896 vs 256).
* **Zero-outlier layers** (Table 5): layers whose maximum quantization
  scale falls below a threshold ``T`` drop their outliers entirely,
  removing all mixed-precision overhead for those layers.

The policy is a plain function from (layer name, input width, calibration
stats) to a :class:`LayerPlan`, so schedulers/benches can query it without
touching model code.
"""

from __future__ import annotations

from dataclasses import dataclass, replace

from . import outliers as outliers_mod

# Layer-name fragments identifying the sensitive second MLP projection.
DOWN_PROJ_NAMES = ("down_proj", "fc2")


@dataclass(frozen=True)
class LayerPlan:
    """Resolved precision decision for one linear layer."""

    weight_bits: int          # 4, 8, or 16 (16 = keep FP)
    act_bits: int             # 4, 8, or 16
    n_outlier: int            # FP16 outlier feature columns
    sparsity: str = "dense"   # "dense" | "2:4"

    @property
    def is_quantized(self) -> bool:
        return self.weight_bits < 16


@dataclass(frozen=True)
class QuikPolicy:
    """Model-wide policy knobs (paper defaults: 256 outliers, 8-bit down-proj).

    ``n_outlier`` is an absolute count as in the paper's main experiments
    ("we employed 256 outliers across all linear modules"); it is clamped
    to at most ``max_outlier_frac`` of the layer's input width so tiny
    reproduction models keep a sensible base block.
    """

    weight_bits: int = 4
    act_bits: int = 4
    n_outlier: int = 256
    down_proj_bits: int = 8           # Table 7: 4-bit down-proj loses >2 ppl
    down_proj_outlier_mult: float = 3.5  # Table 8: 896 ≈ 3.5 × 256
    zero_outlier_threshold: float = 0.0  # Table 5's T; 0 disables the rule
    max_outlier_frac: float = 0.5
    sparsity: str = "dense"
    sparse_dense_layers: tuple[str, ...] = ()  # layer fragments kept dense

    def plan_for(
        self,
        layer_name: str,
        in_features: int,
        stats: outliers_mod.CalibStats | None = None,
    ) -> LayerPlan:
        """Resolve the precision plan for one layer."""
        is_down = any(f in layer_name for f in DOWN_PROJ_NAMES)
        w_bits = self.down_proj_bits if is_down else self.weight_bits
        a_bits = self.down_proj_bits if is_down else self.act_bits

        n_out = self.n_outlier
        if is_down and n_out > 0:
            # Scale the outlier budget with the (wider) down-proj input.
            n_out = int(round(n_out * self.down_proj_outlier_mult))
        n_out = min(n_out, int(in_features * self.max_outlier_frac))

        # Table 5 zero-outlier rule: drop outliers from tame layers.
        if (
            n_out > 0
            and self.zero_outlier_threshold > 0
            and stats is not None
            and outliers_mod.max_scale(stats, a_bits, n_out)
            < self.zero_outlier_threshold
        ):
            n_out = 0

        sparsity = self.sparsity
        if sparsity != "dense" and any(
            f in layer_name for f in self.sparse_dense_layers
        ):
            sparsity = "dense"
        return LayerPlan(
            weight_bits=w_bits, act_bits=a_bits, n_outlier=n_out,
            sparsity=sparsity,
        )

    def with_(self, **kw) -> "QuikPolicy":
        """Functional update helper for ablation sweeps."""
        return replace(self, **kw)


# Canonical configurations used throughout the experiments.
QUIK_4B = QuikPolicy()                                   # headline scheme
QUIK_8B = QuikPolicy(weight_bits=8, act_bits=8, down_proj_bits=8)
QUIK_4B_NO_OUTLIERS = QuikPolicy(n_outlier=0)
QUIK_4B_DOWN4 = QuikPolicy(down_proj_bits=4)             # Table 7 ablation
FP16 = QuikPolicy(weight_bits=16, act_bits=16, n_outlier=0, down_proj_bits=16)
