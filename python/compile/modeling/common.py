"""Decoder-only transformer forward passes (LLaMA-2 / OPT / Falcon styles).

Pure-JAX (no flax): parameters are nested dicts of arrays, the forward is a
function, and every linear layer goes through an ``apply_linear(name, x, p)``
callback so one implementation serves four callers:

1. FP16 evaluation (default callback: ``x @ w.T + b``),
2. calibration capture (callback records layer inputs, then computes FP),
3. quantized evaluation (callback looks up a ``QuantizedLinear``),
4. AOT export (callback routes through the Pallas QUIK kernels so the
   whole quantized pipeline lowers into one HLO artifact).

The linear layer *names* (``q_proj``…``down_proj``/``fc2``) are the keys the
precision policy matches on (``compile.quik.policy``).
"""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable

import numpy as np
import jax
import jax.numpy as jnp

Params = dict
ApplyLinear = Callable[[str, jnp.ndarray, Params], jnp.ndarray]


@dataclass(frozen=True)
class ModelConfig:
    """Architecture description for all three families."""

    family: str = "llama"        # "llama" | "opt" | "falcon"
    vocab: int = 256
    d_model: int = 128
    n_layers: int = 4
    n_heads: int = 4
    d_ff: int = 352              # llama: SwiGLU hidden; opt/falcon: 4*d
    max_seq: int = 256
    # Outlier-feature seeding: a handful of residual channels get a large
    # norm gain at init; training keeps them large, reproducing the
    # documented 100x activation-outlier phenomenon at tiny scale (with
    # gain 25 the trained models show ~25-70x feature-wise linf spread —
    # DESIGN.md §2 Substitutions).
    n_seeded_outliers: int = 6
    outlier_gain: float = 25.0

    @property
    def d_head(self) -> int:
        return self.d_model // self.n_heads

    @property
    def norm_type(self) -> str:
        return "rmsnorm" if self.family == "llama" else "layernorm"

    @property
    def has_bias(self) -> bool:
        return self.family == "opt"

    @property
    def parallel_attn(self) -> bool:
        return self.family == "falcon"

    def linear_names(self) -> list[str]:
        """Names of the per-block linear layers, in forward order."""
        attn = ["q_proj", "k_proj", "v_proj", "o_proj"]
        if self.family == "llama":
            mlp = ["gate_proj", "up_proj", "down_proj"]
        else:
            mlp = ["fc1", "fc2"]
        return attn + mlp

    def linear_shape(self, name: str) -> tuple[int, int]:
        """``(out_features, in_features)`` of a per-block linear layer."""
        d, f = self.d_model, self.d_ff
        return {
            "q_proj": (d, d), "k_proj": (d, d), "v_proj": (d, d),
            "o_proj": (d, d),
            "gate_proj": (f, d), "up_proj": (f, d), "down_proj": (d, f),
            "fc1": (f, d), "fc2": (d, f),
        }[name]

    def num_params(self) -> int:
        n = self.vocab * self.d_model  # tied embedding / lm head
        norm_width = self.d_model * (2 if self.norm_type == "layernorm" else 1)
        for _ in range(self.n_layers):
            for name in self.linear_names():
                o, i = self.linear_shape(name)
                n += o * i + (o if self.has_bias else 0)
            n += norm_width * (1 if self.parallel_attn else 2)
        n += norm_width  # final norm
        if self.family == "opt":
            n += self.max_seq * self.d_model  # learned positions
        return n


def init_params(cfg: ModelConfig, seed: int = 0) -> Params:
    """Initialize parameters (scaled-normal init, tied LM head)."""
    r = np.random.default_rng(seed)

    def dense(o, i, std=None):
        std = std if std is not None else (1.0 / np.sqrt(i))
        return jnp.asarray(r.normal(0.0, std, size=(o, i)).astype(np.float32))

    p: Params = {
        "embed": dense(cfg.vocab, cfg.d_model, std=0.02 * np.sqrt(cfg.d_model)),
        "final_norm": _init_norm(cfg, r),
        "layers": [],
    }
    if cfg.family == "opt":
        p["pos_embed"] = dense(cfg.max_seq, cfg.d_model, std=0.02)
    for _ in range(cfg.n_layers):
        lp: Params = {"attn_norm": _init_norm(cfg, r)}
        if not cfg.parallel_attn:
            lp["mlp_norm"] = _init_norm(cfg, r)
        for name in cfg.linear_names():
            o, i = cfg.linear_shape(name)
            lp[name] = {"w": dense(o, i)}
            if cfg.has_bias:
                lp[name]["b"] = jnp.zeros(o, jnp.float32)
        p["layers"].append(lp)
    return p


def _init_norm(cfg: ModelConfig, r: np.random.Generator) -> Params:
    """Norm gain with seeded outlier channels (see ModelConfig docstring)."""
    g = np.ones(cfg.d_model, np.float32)
    if cfg.n_seeded_outliers:
        idx = r.choice(cfg.d_model, cfg.n_seeded_outliers, replace=False)
        g[idx] = cfg.outlier_gain
    out: Params = {"g": jnp.asarray(g)}
    if cfg.norm_type == "layernorm":
        out["b"] = jnp.zeros(cfg.d_model, jnp.float32)
    return out


# ---------------------------------------------------------------------------
# building blocks
# ---------------------------------------------------------------------------


def norm(x: jnp.ndarray, p: Params, kind: str) -> jnp.ndarray:
    if kind == "rmsnorm":
        ms = jnp.mean(x * x, axis=-1, keepdims=True)
        return x * jax.lax.rsqrt(ms + 1e-6) * p["g"]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + 1e-6) * p["g"] + p["b"]


def rope(x: jnp.ndarray, positions: jnp.ndarray) -> jnp.ndarray:
    """Rotary position embedding over ``[B, H, S, Dh]``."""
    dh = x.shape[-1]
    half = dh // 2
    freqs = 1.0 / (10000.0 ** (jnp.arange(half, dtype=jnp.float32) / half))
    angles = positions[:, None].astype(jnp.float32) * freqs[None, :]  # [S, half]
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = x[..., :half], x[..., half:]
    return jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)


def _default_apply(name: str, x: jnp.ndarray, p: Params) -> jnp.ndarray:
    y = jnp.matmul(x, p["w"].T)
    if "b" in p:
        y = y + p["b"]
    return y


def attention(
    x: jnp.ndarray,
    lp: Params,
    cfg: ModelConfig,
    apply_linear: ApplyLinear,
    prefix: str,
    positions: jnp.ndarray,
    kv_cache: tuple[jnp.ndarray, jnp.ndarray] | None = None,
    causal_offset: int = 0,
) -> tuple[jnp.ndarray, tuple[jnp.ndarray, jnp.ndarray]]:
    """Causal multi-head attention ``[B, S, D] → [B, S, D]``.

    When ``kv_cache=(k_past, v_past)`` is given (decode path) the new keys
    and values are appended and attention spans the concatenation; the
    updated cache is returned either way.
    """
    b, s, d = x.shape
    h, dh = cfg.n_heads, cfg.d_head
    flat = x.reshape(b * s, d)

    def lin(name):
        return apply_linear(f"{prefix}.{name}", flat, lp[name]).reshape(b, s, h, dh).transpose(0, 2, 1, 3)

    q, k, v = lin("q_proj"), lin("k_proj"), lin("v_proj")
    if cfg.family == "llama" or cfg.family == "falcon":
        q = rope(q, positions)
        k = rope(k, positions)
    if kv_cache is not None:
        k = jnp.concatenate([kv_cache[0], k], axis=2)
        v = jnp.concatenate([kv_cache[1], v], axis=2)
    t = k.shape[2]

    scores = jnp.einsum("bhsd,bhtd->bhst", q, k) / np.sqrt(dh)
    # Causal mask: query i (absolute position causal_offset + i) attends to
    # keys with absolute position ≤ its own.
    qpos = jnp.arange(s)[:, None] + causal_offset
    kpos = jnp.arange(t)[None, :]
    mask = kpos <= qpos
    scores = jnp.where(mask[None, None], scores, -1e9)
    probs = jax.nn.softmax(scores, axis=-1)
    ctx = jnp.einsum("bhst,bhtd->bhsd", probs, v)
    ctx = ctx.transpose(0, 2, 1, 3).reshape(b * s, d)
    out = apply_linear(f"{prefix}.o_proj", ctx, lp["o_proj"]).reshape(b, s, d)
    return out, (k, v)


def mlp(
    x: jnp.ndarray, lp: Params, cfg: ModelConfig,
    apply_linear: ApplyLinear, prefix: str,
) -> jnp.ndarray:
    b, s, d = x.shape
    flat = x.reshape(b * s, d)
    if cfg.family == "llama":
        gate = apply_linear(f"{prefix}.gate_proj", flat, lp["gate_proj"])
        up = apply_linear(f"{prefix}.up_proj", flat, lp["up_proj"])
        # SwiGLU: the Hadamard product multiplies the two branches' variances
        # together — the root cause of the down-proj sensitivity (Fig. 10).
        hidden = jax.nn.silu(gate) * up
        out = apply_linear(f"{prefix}.down_proj", hidden, lp["down_proj"])
    else:
        hidden = jax.nn.gelu(apply_linear(f"{prefix}.fc1", flat, lp["fc1"]))
        out = apply_linear(f"{prefix}.fc2", hidden, lp["fc2"])
    return out.reshape(b, s, d)


def forward(
    params: Params,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    apply_linear: ApplyLinear = _default_apply,
    kv_caches: list | None = None,
    position_offset: int = 0,
) -> tuple[jnp.ndarray, list]:
    """Full forward ``int32[B, S] → f32[B, S, V]`` logits.

    ``kv_caches``/``position_offset`` implement incremental decoding: pass
    the caches returned by the prefill call and ``offset = context length``.
    Returns ``(logits, new_kv_caches)``.
    """
    b, s = tokens.shape
    x = params["embed"][tokens]
    positions = jnp.arange(s) + position_offset
    if cfg.family == "opt":
        x = x + params["pos_embed"][positions]

    new_caches = []
    for li, lp in enumerate(params["layers"]):
        prefix = f"layers.{li}"
        cache = kv_caches[li] if kv_caches is not None else None
        if cfg.parallel_attn:
            # Falcon: one shared norm feeds attention AND the MLP — the
            # layout that defeats SmoothQuant's LayerNorm scale folding.
            h = norm(x, lp["attn_norm"], cfg.norm_type)
            attn_out, new_cache = attention(
                h, lp, cfg, apply_linear, f"{prefix}.self_attn", positions,
                cache, position_offset,
            )
            mlp_out = mlp(h, lp, cfg, apply_linear, f"{prefix}.mlp")
            x = x + attn_out + mlp_out
        else:
            h = norm(x, lp["attn_norm"], cfg.norm_type)
            attn_out, new_cache = attention(
                h, lp, cfg, apply_linear, f"{prefix}.self_attn", positions,
                cache, position_offset,
            )
            x = x + attn_out
            h = norm(x, lp["mlp_norm"], cfg.norm_type)
            x = x + mlp(h, lp, cfg, apply_linear, f"{prefix}.mlp")
        new_caches.append(new_cache)

    x = norm(x, params["final_norm"], cfg.norm_type)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])  # tied head
    return logits, new_caches


def forward_with_cache(
    params: Params,
    tokens: jnp.ndarray,
    cfg: ModelConfig,
    cache_k: jnp.ndarray,
    cache_v: jnp.ndarray,
    cache_len: jnp.ndarray,
    apply_linear: ApplyLinear = _default_apply,
) -> tuple[jnp.ndarray, jnp.ndarray, jnp.ndarray]:
    """Serving-path forward with **fixed-size** KV-cache buffers.

    This is the function the AOT artifacts are lowered from: the Rust
    coordinator owns the cache buffers and threads them through PJRT calls.

    Args:
      tokens: ``int32[B, S_new]`` — the prompt for prefill (``cache_len=0``)
        or a single generated token (``S_new=1``) for decode.
      cache_k / cache_v: ``f32[L, B, H, T_max, Dh]`` persistent buffers.
      cache_len: ``int32[]`` tokens already in the cache.

    Returns:
      ``(logits[B, S_new, V], cache_k, cache_v)`` with the new tokens'
      keys/values written at ``cache_len .. cache_len+S_new``.
    """
    b, s = tokens.shape
    h, dh = cfg.n_heads, cfg.d_head
    t_max = cache_k.shape[3]
    x = params["embed"][tokens]
    positions = jnp.arange(s) + cache_len
    if cfg.family == "opt":
        x = x + params["pos_embed"][positions]

    def attn_cached(xn, lp, li, prefix):
        flat = xn.reshape(b * s, cfg.d_model)

        def lin(name):
            return (
                apply_linear(f"{prefix}.{name}", flat, lp[name])
                .reshape(b, s, h, dh).transpose(0, 2, 1, 3)
            )

        q, k, v = lin("q_proj"), lin("k_proj"), lin("v_proj")
        if cfg.family in ("llama", "falcon"):
            q = rope(q, positions)
            k = rope(k, positions)
        ck = jax.lax.dynamic_update_slice(
            cache_k[li], k, (0, 0, cache_len, 0)
        )
        cv = jax.lax.dynamic_update_slice(
            cache_v[li], v, (0, 0, cache_len, 0)
        )
        scores = jnp.einsum("bhsd,bhtd->bhst", q, ck) / np.sqrt(dh)
        qpos = jnp.arange(s)[:, None] + cache_len          # absolute
        kpos = jnp.arange(t_max)[None, :]
        mask = kpos <= qpos                                 # causal + length
        scores = jnp.where(mask[None, None], scores, -1e9)
        probs = jax.nn.softmax(scores, axis=-1)
        ctx = jnp.einsum("bhst,bhtd->bhsd", probs, cv)
        ctx = ctx.transpose(0, 2, 1, 3).reshape(b * s, cfg.d_model)
        out = apply_linear(f"{prefix}.o_proj", ctx, lp["o_proj"]).reshape(b, s, cfg.d_model)
        return out, ck, cv

    new_k, new_v = [], []
    for li, lp in enumerate(params["layers"]):
        prefix = f"layers.{li}"
        if cfg.parallel_attn:
            hn = norm(x, lp["attn_norm"], cfg.norm_type)
            attn_out, ck, cv = attn_cached(hn, lp, li, f"{prefix}.self_attn")
            mlp_out = mlp(hn, lp, cfg, apply_linear, f"{prefix}.mlp")
            x = x + attn_out + mlp_out
        else:
            hn = norm(x, lp["attn_norm"], cfg.norm_type)
            attn_out, ck, cv = attn_cached(hn, lp, li, f"{prefix}.self_attn")
            x = x + attn_out
            hn = norm(x, lp["mlp_norm"], cfg.norm_type)
            x = x + mlp(hn, lp, cfg, apply_linear, f"{prefix}.mlp")
        new_k.append(ck)
        new_v.append(cv)

    x = norm(x, params["final_norm"], cfg.norm_type)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])
    return logits, jnp.stack(new_k), jnp.stack(new_v)


def make_capture_apply(store: dict[str, list]) -> ApplyLinear:
    """Calibration callback: record each linear layer's input, compute FP."""

    def apply(name: str, x: jnp.ndarray, p: Params) -> jnp.ndarray:
        store.setdefault(name, []).append(np.asarray(x))
        return _default_apply(name, x, p)

    return apply


def make_quantized_apply(
    qlayers: dict[str, "object"], use_kernels: bool = False
) -> ApplyLinear:
    """Quantized-inference callback: route through ``QuantizedLinear``s.

    Layers absent from ``qlayers`` (e.g. excluded by policy) fall back to
    the FP16 path using the original parameters.
    """

    def apply(name: str, x: jnp.ndarray, p: Params) -> jnp.ndarray:
        ql = qlayers.get(name)
        if ql is None:
            return _default_apply(name, x, p)
        return ql(x, use_kernels=use_kernels)

    return apply
