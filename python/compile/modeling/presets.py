"""Model zoo presets: tiny runnable configs + paper-scale shape specs.

Two kinds of entries:

* ``TINY`` — models small enough to pretrain on the synthetic corpus in
  minutes on CPU; all accuracy experiments (Tables 1-5, 7-14) run on these.
  The three llama sizes mirror the paper's 7B/13B/70B size sweep (Fig. 1).
* ``PAPER_SCALE`` — the exact layer shapes of the models the paper
  benchmarks; consumed by the analytic device/memory models (both here and
  in ``rust/src/config`` — ``make artifacts`` emits ``model_zoo.json`` so
  the Rust side can verify parity in its tests).

Paper-scale notes: LLaMA2-70B uses grouped-query attention in reality; the
shape spec keeps full MHA k/v projections scaled to the published parameter
count (the FLOP/memory deltas are <2% and affect no conclusion — see
DESIGN.md §2).
"""

from __future__ import annotations

from .common import ModelConfig

# ---------------------------------------------------------------------------
# tiny runnable models (trained on the synthetic corpus)
# ---------------------------------------------------------------------------

TINY: dict[str, ModelConfig] = {
    # LLaMA-style size ladder (stands in for 7B / 13B / 70B).
    "llama-s": ModelConfig(family="llama", d_model=96, n_layers=3, n_heads=4, d_ff=256),
    "llama-m": ModelConfig(family="llama", d_model=128, n_layers=4, n_heads=4, d_ff=352),
    "llama-l": ModelConfig(family="llama", d_model=192, n_layers=6, n_heads=6, d_ff=512),
    # OPT-style and Falcon-style mid-size models.
    "opt-m": ModelConfig(family="opt", d_model=128, n_layers=4, n_heads=4, d_ff=512),
    "falcon-m": ModelConfig(family="falcon", d_model=128, n_layers=4, n_heads=4, d_ff=512),
}

# Default outlier budget for tiny models: 1/8 of d_model, matching the
# paper's note that 256 outliers ≈ 12.5% of OPT-1.3b's hidden size.
def tiny_outliers(cfg: ModelConfig) -> int:
    return max(4, cfg.d_model // 8)


# ---------------------------------------------------------------------------
# paper-scale shape specs (for the device & memory models)
# ---------------------------------------------------------------------------

def _spec(family, d_model, n_layers, n_heads, n_kv_heads, d_ff, vocab, max_seq=2048):
    return dict(
        family=family, d_model=d_model, n_layers=n_layers, n_heads=n_heads,
        n_kv_heads=n_kv_heads, d_ff=d_ff, vocab=vocab, max_seq=max_seq,
    )

# n_kv_heads < n_heads marks grouped-query (LLaMA2-70B, Falcon-40B/180B)
# and multi-query (Falcon-7B) attention; mirrored in rust/src/config.
PAPER_SCALE: dict[str, dict] = {
    # OPT family (Zhang et al. 2022), vocab 50272.
    "opt-1.3b": _spec("opt", 2048, 24, 32, 32, 8192, 50272),
    "opt-6.7b": _spec("opt", 4096, 32, 32, 32, 16384, 50272),
    "opt-13b": _spec("opt", 5120, 40, 40, 40, 20480, 50272),
    "opt-30b": _spec("opt", 7168, 48, 56, 56, 28672, 50272),
    "opt-66b": _spec("opt", 9216, 64, 72, 72, 36864, 50272),
    # LLaMA-2 family (Touvron et al. 2023), vocab 32000.
    "llama2-7b": _spec("llama", 4096, 32, 32, 32, 11008, 32000, 4096),
    "llama2-13b": _spec("llama", 5120, 40, 40, 40, 13824, 32000, 4096),
    "llama2-70b": _spec("llama", 8192, 80, 64, 8, 28672, 32000, 4096),
    # Falcon family (TII UAE 2023), vocab 65024.
    "falcon-7b": _spec("falcon", 4544, 32, 71, 1, 18176, 65024),
    "falcon-40b": _spec("falcon", 8192, 60, 128, 8, 32768, 65024),
    "falcon-180b": _spec("falcon", 14848, 80, 232, 8, 59392, 65024),
}


def paper_linear_shapes(name: str) -> list[tuple[str, int, int]]:
    """Per-block linear layers ``(name, out, in)`` of a paper-scale model."""
    s = PAPER_SCALE[name]
    d, f = s["d_model"], s["d_ff"]
    kv = s["n_kv_heads"] * (d // s["n_heads"])
    attn = [("q_proj", d, d), ("k_proj", kv, d), ("v_proj", kv, d), ("o_proj", d, d)]
    if s["family"] == "llama":
        mlp = [("gate_proj", f, d), ("up_proj", f, d), ("down_proj", d, f)]
    else:
        mlp = [("fc1", f, d), ("fc2", d, f)]
    return attn + mlp
