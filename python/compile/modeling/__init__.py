"""L2 model zoo: decoder-only transformers in plain JAX.

``common`` implements the three block flavours the paper evaluates —

* **LLaMA-2 style**: RMSNorm, rotary embeddings, SwiGLU MLP
  (gate/up/down — the down-projection input is the Hadamard product whose
  variance blow-up drives the 8-bit down-proj policy, Fig. 10),
* **OPT style**: LayerNorm, learned positions, GeLU MLP (fc1/fc2), biases,
* **Falcon style**: parallel attention + MLP sharing one LayerNorm (the
  layout that breaks SmoothQuant's scale folding, §4.1).

Every linear layer is routed through an injectable ``apply_linear``
callback, which is how the same forward serves FP16 evaluation,
calibration capture, quantized evaluation and Pallas-kernel AOT export.
``presets`` names the tiny reproduction configs and the paper-scale shape
specs shared with ``rust/src/config``.
"""

from . import common, presets  # noqa: F401
