"""Experiment runner: regenerates every accuracy table/figure of the paper.

Usage::

    python -m compile.experiments all          # everything (slow-ish)
    python -m compile.experiments tab1 tab8    # selected experiments
    python -m compile.experiments tab1 --fast  # smaller evals for smoke runs

Each experiment prints a markdown table mirroring the paper's and appends
its rows to ``artifacts/experiments/<exp>.json`` so EXPERIMENTS.md can
quote exact numbers.  Results are cached by configuration fingerprint —
delete ``artifacts/experiments`` to force recomputation.

Experiment ↔ paper mapping (DESIGN.md §5): tab1/tab2/tab3/tab4(=tab12)/
tab5(=tab13)/tab7/tab8/tab9(+tab14)/tab10/tab11, fig1, fig10.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import pathlib
import sys

import numpy as np

from . import data, evals, model, train
from .modeling import presets
from .quik import policy as policy_mod

OUT_DIR = pathlib.Path(__file__).resolve().parents[2] / "artifacts" / "experiments"

# eval sizes: (train_steps, eval_tokens, zero_shot_items)
FULL = (400, 24_576, 64)
FAST = (150, 8_192, 32)


class Runner:
    def __init__(self, fast: bool = False):
        self.steps, self.eval_tokens, self.zs_items = FAST if fast else FULL
        OUT_DIR.mkdir(parents=True, exist_ok=True)
        self.cache_path = OUT_DIR / "cache.json"
        self.cache = (
            json.loads(self.cache_path.read_text()) if self.cache_path.exists() else {}
        )
        self._models: dict = {}

    # -- infrastructure ----------------------------------------------------

    def get_model(self, name: str):
        if name not in self._models:
            cfg, params, _ = train.load_or_train(name, steps=self.steps)
            calib = data.calibration_sequences("pile", 64, 128, seed=1)[:, :-1]
            ci = model.calibrate(params, cfg, calib)
            self._models[name] = (cfg, params, ci)
        return self._models[name]

    def _key(self, *parts) -> str:
        blob = json.dumps([self.steps, self.eval_tokens, *parts], sort_keys=True, default=str)
        return hashlib.sha256(blob.encode()).hexdigest()[:16]

    def ppl(self, model_name: str, scheme: str | None, pol: policy_mod.QuikPolicy | None,
            split: str = "wikitext2", clip: bool = True, alpha: float = 0.5) -> float:
        """Perplexity of (model, quantization config) on an eval split."""
        key = self._key("ppl", model_name, scheme, pol.__dict__ if pol else None,
                        split, clip, alpha)
        if key in self.cache:
            return self.cache[key]
        cfg, params, ci = self.get_model(model_name)
        if scheme is None:
            fwd = model.make_forward(None, params, cfg)
        else:
            qm = model.quantize_model(params, cfg, ci, pol, scheme=scheme,
                                      clip=clip, alpha=alpha)
            fwd = model.make_forward(qm, params, cfg)
        val = evals.perplexity(fwd, split=split, n_tokens=self.eval_tokens)
        self.cache[key] = val
        self.cache_path.write_text(json.dumps(self.cache, indent=0))
        return val

    def zero_outlier_layers(self, model_name: str, pol: policy_mod.QuikPolicy) -> int:
        cfg, params, ci = self.get_model(model_name)
        qm = model.quantize_model(params, cfg, ci, pol, scheme="quik")
        return qm.zero_outlier_layer_count()

    def save(self, exp: str, table: dict):
        (OUT_DIR / f"{exp}.json").write_text(json.dumps(table, indent=1))

    def tiny_pol(self, model_name: str, **kw) -> policy_mod.QuikPolicy:
        cfg = presets.TINY[model_name]
        base = dict(n_outlier=presets.tiny_outliers(cfg))
        if cfg.family == "opt":
            # paper: OPT gets uniform outliers, no 8-bit down-proj exception
            base.update(down_proj_bits=kw.get("weight_bits", 4),
                        down_proj_outlier_mult=1.0)
        base.update(kw)
        return policy_mod.QuikPolicy(**base)


def md_table(headers: list[str], rows: list[list]) -> str:
    out = ["| " + " | ".join(headers) + " |",
           "|" + "|".join(["---"] * len(headers)) + "|"]
    for r in rows:
        out.append("| " + " | ".join(str(c) for c in r) + " |")
    return "\n".join(out)


# ---------------------------------------------------------------------------
# experiments
# ---------------------------------------------------------------------------


def tab1(r: Runner):
    """Table 1 — 4-bit OPT perplexity: QUIK vs baselines (WikiText2)."""
    m = "opt-m"
    rows = [
        ["Baseline FP16", round(r.ppl(m, None, None), 3)],
        ["SmoothQuant W4A4", round(r.ppl(m, "smoothquant",
            r.tiny_pol(m, n_outlier=0), alpha=0.5), 3)],
        ["RTN W4A4 (0 outliers)", round(r.ppl(m, "rtn", r.tiny_pol(m, n_outlier=0)), 3)],
        ["QUIK-4B (ours)", round(r.ppl(m, "quik", r.tiny_pol(m)), 3)],
    ]
    print("\n### Table 1 — OPT 4-bit perplexity (WikiText2, tiny-OPT)\n")
    print(md_table(["method", "ppl"], rows))
    r.save("tab1", {"rows": rows})


def tab2(r: Runner):
    """Table 2 — LLaMA-2 / Falcon 4-bit perplexity."""
    rows = []
    for m in ["llama-s", "llama-m", "llama-l", "falcon-m"]:
        fp = r.ppl(m, None, None)
        sq = r.ppl(m, "smoothquant", r.tiny_pol(m, n_outlier=0), alpha=0.8)
        qk = r.ppl(m, "quik", r.tiny_pol(m))
        rows.append([m, round(fp, 3), round(sq, 3), round(qk, 3),
                     round(qk - fp, 3)])
    print("\n### Table 2 — LLaMA/Falcon 4-bit perplexity (WikiText2)\n")
    print(md_table(["model", "FP16", "SmoothQuant-4b", "QUIK-4B", "Δppl"], rows))
    r.save("tab2", {"rows": rows})


def tab3(r: Runner):
    """Table 3 — zero-shot task accuracy, FP16 vs QUIK-4B."""
    rows = []
    for m in ["opt-m", "llama-m", "llama-l"]:
        cfg, params, ci = r.get_model(m)
        fwd_fp = model.make_forward(None, params, cfg)
        accs_fp = evals.zero_shot_suite(fwd_fp, n_items=r.zs_items)
        qm = model.quantize_model(params, cfg, ci, r.tiny_pol(m), scheme="quik")
        fwd_q = model.make_forward(qm, params, cfg)
        accs_q = evals.zero_shot_suite(fwd_q, n_items=r.zs_items)
        for tag, a in [("FP16", accs_fp), ("QUIK-4B", a2 := accs_q)]:
            rows.append([m, tag] + [round(a[t] * 100, 1) for t in evals.TASKS]
                        + [round(a["avg"] * 100, 1)])
    print("\n### Table 3 — zero-shot accuracy (synthetic suite)\n")
    print(md_table(["model", "bits", *evals.TASKS, "avg"], rows))
    r.save("tab3", {"rows": rows})


def tab4(r: Runner):
    """Tables 4/12 — 8-bit: QUIK-8B vs SmoothQuant (near-lossless)."""
    rows = []
    for m in ["opt-m", "llama-m", "falcon-m"]:
        alpha = 0.8 if m.startswith("llama") else 0.5
        fp = r.ppl(m, None, None)
        sq = r.ppl(m, "smoothquant",
                   r.tiny_pol(m, weight_bits=8, act_bits=8, n_outlier=0),
                   alpha=alpha)
        q8 = r.ppl(m, "quik", r.tiny_pol(m, weight_bits=8, act_bits=8))
        rows.append([m, round(fp, 3), round(sq, 3), round(q8, 3)])
    print("\n### Table 4/12 — 8-bit perplexity (WikiText2)\n")
    print(md_table(["model", "FP16", "SmoothQuant-8b", "QUIK-8B"], rows))
    r.save("tab4", {"rows": rows})


def tab5(r: Runner):
    """Tables 5/13 — zero-outlier threshold T sweep."""
    rows = []
    for m in ["llama-m", "falcon-m"]:
        fp = r.ppl(m, None, None)
        for t in [0.0, 0.05, 0.1, 0.2, 0.4]:
            pol = r.tiny_pol(m, zero_outlier_threshold=t)
            ppl = r.ppl(m, "quik", pol)
            nz = r.zero_outlier_layers(m, pol)
            rows.append([m, t, round(ppl, 3), nz, round(fp, 3)])
    print("\n### Table 5/13 — zero-outlier threshold sweep\n")
    print(md_table(["model", "T", "ppl", "#layers w/o outliers", "FP16 ppl"], rows))
    r.save("tab5", {"rows": rows})


def tab7(r: Runner):
    """Table 7 — 8-bit vs 4-bit down-projection ablation (LLaMA)."""
    rows = []
    for m in ["llama-s", "llama-m", "llama-l"]:
        fp = r.ppl(m, None, None)
        q8 = r.ppl(m, "quik", r.tiny_pol(m, down_proj_bits=8))
        q4 = r.ppl(m, "quik", r.tiny_pol(m, down_proj_bits=4))
        rows.append([m, round(fp, 3), round(q8, 3), round(q4, 3)])
    print("\n### Table 7 — down-projection precision ablation\n")
    print(md_table(["model", "FP16", "QUIK-4B (8b down)", "4-bit down"], rows))
    r.save("tab7", {"rows": rows})


def tab8(r: Runner):
    """Table 8 — outlier-count sweep on the largest tiny-LLaMA."""
    m = "llama-l"
    cfg = presets.TINY[m]
    fp = r.ppl(m, None, None)
    rows = [["FP16", "-", round(fp, 3)]]
    for n_out in [0, cfg.d_model // 32, cfg.d_model // 16, cfg.d_model // 8,
                  cfg.d_model // 4]:
        ppl = r.ppl(m, "quik", r.tiny_pol(m, n_outlier=n_out))
        down = int(round(n_out * 3.5))
        rows.append([f"QUIK-4B {n_out} outliers", down, round(ppl, 3)])
    print("\n### Table 8 — outlier count ablation (llama-l)\n")
    print(md_table(["config", "down-proj outliers", "ppl"], rows))
    r.save("tab8", {"rows": rows})


def tab9(r: Runner):
    """Tables 9/14 — joint 2:4 sparsity + quantization (Falcon-style)."""
    m = "falcon-m"
    fp = r.ppl(m, None, None)
    rows = [["FP16 dense", "-", round(fp, 3)]]
    cases = [
        ("QUIK-4B dense", r.tiny_pol(m), "quik"),
        ("QUIK-4B 2:4 all", r.tiny_pol(m, sparsity="2:4"), "sparse_quik"),
        ("QUIK-4B 2:4, attn dense",
         r.tiny_pol(m, sparsity="2:4",
                    sparse_dense_layers=("q_proj", "k_proj", "v_proj", "o_proj")),
         "sparse_quik"),
        ("QUIK-4B 2:4, MLP dense",
         r.tiny_pol(m, sparsity="2:4", sparse_dense_layers=("fc1", "fc2")),
         "sparse_quik"),
        ("QUIK-8B 2:4 all",
         r.tiny_pol(m, weight_bits=8, act_bits=8, sparsity="2:4"), "sparse_quik"),
    ]
    for name, pol, scheme in cases:
        rows.append([name, pol.sparsity, round(r.ppl(m, scheme, pol), 3)])
    print("\n### Table 9/14 — 2:4 sparsity + quantization (falcon-m)\n")
    print(md_table(["config", "sparsity", "ppl"], rows))
    r.save("tab9", {"rows": rows})


def tab10(r: Runner):
    """Table 10 — OPT perplexity across datasets × outlier counts."""
    m = "opt-m"
    cfg = presets.TINY[m]
    splits = ["wikitext2", "ptb", "c4"]
    rows = []
    rows.append(["Baseline FP16"] + [round(r.ppl(m, None, None, split=s), 3) for s in splits])
    wonly = r.tiny_pol(m)
    # GPTQ weight-only: activations FP16
    gptq_pol = policy_mod.QuikPolicy(
        n_outlier=presets.tiny_outliers(cfg), act_bits=16, down_proj_bits=4,
        down_proj_outlier_mult=1.0)
    rows.append(["GPTQ-4B (W4A16)"] +
                [round(r.ppl(m, "gptq_wonly", gptq_pol, split=s), 3) for s in splits])
    for n_out in [0, cfg.d_model // 32, cfg.d_model // 16, cfg.d_model // 8]:
        pol = r.tiny_pol(m, n_outlier=n_out)
        rows.append([f"{n_out} outliers"] +
                    [round(r.ppl(m, "quik", pol, split=s), 3) for s in splits])
    print("\n### Table 10 — OPT across datasets × outliers\n")
    print(md_table(["config", *splits], rows))
    r.save("tab10", {"rows": rows})


def tab11(r: Runner):
    """Table 11 — LLaMA tricks ladder (GPTQ → QUIK + clipping)."""
    rows = []
    for m in ["llama-s", "llama-m", "llama-l"]:
        fp = r.ppl(m, None, None)
        gptq_pol = r.tiny_pol(m, act_bits=16, down_proj_bits=4)
        g = r.ppl(m, "gptq_wonly", gptq_pol)
        q_d4 = r.ppl(m, "quik", r.tiny_pol(m, down_proj_bits=4))
        q_d8_noclip = r.ppl(m, "quik", r.tiny_pol(m, down_proj_bits=8), clip=False)
        q_d8_clip = r.ppl(m, "quik", r.tiny_pol(m, down_proj_bits=8), clip=True)
        rows.append([m, round(fp, 3), round(g, 3), round(q_d4, 3),
                     round(q_d8_noclip, 3), round(q_d8_clip, 3)])
    print("\n### Table 11 — LLaMA configuration ladder (WikiText2)\n")
    print(md_table(
        ["model", "FP16", "GPTQ W4A16", "QUIK down-4b", "QUIK down-8b", "+clipping"],
        rows))
    r.save("tab11", {"rows": rows})


def fig1(r: Runner):
    """Figure 1 — accuracy + speedup vs model size (LLaMA ladder)."""
    # speedups come from the Rust device model (paper-scale shapes); here
    # we pair the tiny-ladder accuracy with the paper-scale speedup table
    # regenerated by `cargo bench --bench fig9_e2e`.
    rows = []
    for m, paper in [("llama-s", "llama2-7b"), ("llama-m", "llama2-13b"),
                     ("llama-l", "llama2-70b")]:
        fp = r.ppl(m, None, None)
        qk = r.ppl(m, "quik", r.tiny_pol(m))
        rows.append([m, paper, round(fp, 3), round(qk, 3), round(qk - fp, 3)])
    print("\n### Figure 1 — accuracy across the LLaMA size ladder\n")
    print(md_table(["tiny model", "stands for", "FP16 ppl", "QUIK-4B ppl", "Δ"], rows))
    r.save("fig1", {"rows": rows})


def fig10(r: Runner):
    """Figure 10 — input variance by layer kind (down-proj spike)."""
    cfg, params, _ = r.get_model("llama-m")
    var = evals.activation_variance_by_layer(params, cfg)
    rows = [[k, round(v, 3)] for k, v in sorted(var.items(), key=lambda kv: kv[1])]
    print("\n### Figure 10 — input variance per layer kind (llama-m)\n")
    print(md_table(["layer kind", "variance"], rows))
    ratio = var["down_proj"] / max(v for k, v in var.items() if k != "down_proj")
    print(f"\ndown_proj / max(others) variance ratio: {ratio:.1f}x (paper: ≫1) ")
    r.save("fig10", {"rows": rows, "down_proj_ratio": ratio})


EXPERIMENTS = {
    "tab1": tab1, "tab2": tab2, "tab3": tab3, "tab4": tab4, "tab5": tab5,
    "tab7": tab7, "tab8": tab8, "tab9": tab9, "tab10": tab10, "tab11": tab11,
    "fig1": fig1, "fig10": fig10,
}


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("experiments", nargs="+",
                    help=f"one of {list(EXPERIMENTS)} or 'all'")
    ap.add_argument("--fast", action="store_true", help="smaller evals")
    args = ap.parse_args()
    names = list(EXPERIMENTS) if "all" in args.experiments else args.experiments
    r = Runner(fast=args.fast)
    for name in names:
        if name not in EXPERIMENTS:
            print(f"unknown experiment {name!r}", file=sys.stderr)
            sys.exit(2)
        EXPERIMENTS[name](r)


if __name__ == "__main__":
    main()
