"""L2 entry point: calibration, model-level quantization, quantized forward.

This is the layer the AOT exporter (``compile.aot``) and the experiment
runner (``compile.experiments``) drive:

* :func:`calibrate` — run the FP model over calibration sequences and
  capture every linear layer's input (bounded sample per layer, matching
  the paper's 512-Pile-sentence / 128-C4-sample recipe at tiny scale);
* :func:`quantize_model` — resolve the per-layer precision plan via the
  :class:`~compile.quik.policy.QuikPolicy` and quantize each linear with
  the selected scheme (QUIK / RTN / SmoothQuant / GPTQ-weight-only /
  SparseGPT / FP16);
* :func:`make_forward` — the quantized forward, either through the jnp
  oracle (fast eval) or through the Pallas kernels (the path that lowers
  into the AOT HLO artifact).
"""

from __future__ import annotations

from dataclasses import dataclass

import numpy as np
import jax.numpy as jnp

from .modeling import common
from .quik import policy as policy_mod
from .quik import quantize as quantize_mod
from .quik.quantize import QuantizedLinear

# Rows of calibration activations retained per linear layer.  Enough for a
# well-conditioned Hessian at tiny-model widths while bounding memory.
MAX_CALIB_ROWS = 4096


def calibrate(
    params: common.Params,
    cfg: common.ModelConfig,
    calib_tokens: np.ndarray,
    max_rows: int = MAX_CALIB_ROWS,
) -> dict[str, np.ndarray]:
    """Capture per-linear-layer inputs over ``[n_seq, S]`` calibration data.

    Returns ``{layer_name: f32[rows, in_features]}`` with rows capped at
    ``max_rows`` (first-come, which over random calibration sequences is an
    unbiased sample).
    """
    store: dict[str, list] = {}
    apply = common.make_capture_apply(store)
    for i in range(calib_tokens.shape[0]):
        seq = jnp.asarray(calib_tokens[i : i + 1])
        common.forward(params, seq, cfg, apply_linear=apply)
        if all(
            sum(a.shape[0] for a in v) >= max_rows for v in store.values()
        ):
            break
    return {
        name: np.concatenate(chunks, axis=0)[:max_rows]
        for name, chunks in store.items()
    }


@dataclass
class QuantizedModel:
    """A model ready for quantized inference / AOT export."""

    cfg: common.ModelConfig
    params: common.Params                 # original params (norms, embeds, FP fallbacks)
    qlayers: dict[str, QuantizedLinear]   # per-linear quantized packages
    policy: policy_mod.QuikPolicy
    scheme: str

    def forward(
        self,
        tokens: jnp.ndarray,
        use_kernels: bool = False,
        kv_caches=None,
        position_offset: int = 0,
    ):
        apply = common.make_quantized_apply(self.qlayers, use_kernels=use_kernels)
        return common.forward(
            self.params, tokens, self.cfg, apply_linear=apply,
            kv_caches=kv_caches, position_offset=position_offset,
        )

    def zero_outlier_layer_count(self) -> int:
        """Number of linear layers running without any outliers (Table 5)."""
        return sum(
            1 for ql in self.qlayers.values()
            if ql.qw is not None and ql.qw.w_fp.shape[1] == 0
        )


def quantize_model(
    params: common.Params,
    cfg: common.ModelConfig,
    calib_inputs: dict[str, np.ndarray],
    quik_policy: policy_mod.QuikPolicy,
    scheme: str = "quik",
    clip: bool = True,
    alpha: float = 0.5,
) -> QuantizedModel:
    """Quantize every linear layer of the model per the policy.

    ``calib_inputs`` comes from :func:`calibrate` — run on the *Pile* split
    for outlier selection; the Hessians for GPTQ reuse the same captured
    activations (at tiny scale the paper's separate C4 draw adds nothing).
    """
    from .quik import outliers as outliers_mod

    qlayers: dict[str, QuantizedLinear] = {}
    for li, lp in enumerate(params["layers"]):
        for lname in cfg.linear_names():
            section = "self_attn" if lname.endswith("_proj") and lname[0] in "qkvo" else "mlp"
            full = f"layers.{li}.{section}.{lname}"
            x = calib_inputs[full]
            stats = outliers_mod.collect_stats(x)
            plan = quik_policy.plan_for(full, x.shape[1], stats)
            if scheme == "sparse_quik" and plan.sparsity == "dense":
                eff_scheme = "quik" if plan.is_quantized else "fp16"
            else:
                eff_scheme = scheme
            w = np.asarray(lp[lname]["w"])
            b = np.asarray(lp[lname]["b"]) if "b" in lp[lname] else None
            qlayers[full] = quantize_mod.quantize_linear(
                w, x, plan, scheme=eff_scheme, bias=b, clip=clip, alpha=alpha,
            )
    return QuantizedModel(
        cfg=cfg, params=params, qlayers=qlayers,
        policy=quik_policy, scheme=scheme,
    )


def make_forward(qm: QuantizedModel | None, params, cfg, use_kernels=False):
    """Uniform forward closure: quantized when ``qm`` is given, else FP16.

    The cache-less path (what the eval harness hammers) is jitted once per
    input shape; the KV-cache path stays eager (serving goes through the
    AOT artifacts, not this closure).
    """
    import jax

    if qm is None:
        @jax.jit
        def fp_jitted(tokens):
            return common.forward(params, tokens, cfg)[0]

        def fp_forward(tokens, kv_caches=None, position_offset=0):
            if kv_caches is None and position_offset == 0:
                return fp_jitted(tokens), None
            return common.forward(
                params, tokens, cfg, kv_caches=kv_caches,
                position_offset=position_offset,
            )
        return fp_forward

    @jax.jit
    def q_jitted(tokens):
        apply = common.make_quantized_apply(qm.qlayers, use_kernels=use_kernels)
        return common.forward(qm.params, tokens, cfg, apply_linear=apply)[0]

    def q_forward(tokens, kv_caches=None, position_offset=0):
        if kv_caches is None and position_offset == 0:
            return q_jitted(tokens), None
        return qm.forward(
            tokens, use_kernels=use_kernels, kv_caches=kv_caches,
            position_offset=position_offset,
        )
    return q_forward
