"""Evaluation harness: perplexity + synthetic zero-shot task suite.

* :func:`perplexity` — next-token perplexity over deterministic eval
  windows (the WikiText2/PTB/C4 measurements of Tables 1/2/10/...).
* :func:`zero_shot_suite` — five synthetic multiple-choice tasks standing
  in for PIQA / WinoGrande / HellaSwag / ARC-e / ARC-c (Table 3).  Each
  task scores candidate continuations by total log-likelihood under the
  model; chance is 50%.  The *absolute* numbers are not comparable to the
  paper's (different tasks), but the quantization-induced *drop* is the
  quantity Table 3 reports and the one we reproduce.
* :func:`activation_variance_by_layer` — the Figure 10 diagnostic.
"""

from __future__ import annotations

import numpy as np
import jax.numpy as jnp

from . import data
from .modeling import common


def _log_probs(forward, tokens: np.ndarray, batch: int = 16) -> np.ndarray:
    """Per-position log P(next token) for ``[N, S+1]`` windows → ``[N, S]``."""
    import jax

    outs = []
    for i in range(0, tokens.shape[0], batch):
        chunk = jnp.asarray(tokens[i : i + batch])
        inputs, targets = chunk[:, :-1], chunk[:, 1:]
        logits, _ = forward(inputs)
        logp = jnp.take_along_axis(
            jax.nn.log_softmax(logits, axis=-1), targets[..., None], axis=-1
        )[..., 0]
        outs.append(np.asarray(logp))
    return np.concatenate(outs, axis=0)


def perplexity(
    forward,
    split: str = "wikitext2",
    n_tokens: int = 32_768,
    seq: int = 128,
    seed: int = 0,
    batch: int = 16,
) -> float:
    """Corpus perplexity of a forward closure on a named eval split."""
    corpus = data.make_corpus(split, n_tokens, seed=seed)
    windows = data.eval_windows(corpus, seq)
    logp = _log_probs(forward, windows, batch=batch)
    return float(np.exp(-np.mean(logp)))


# ---------------------------------------------------------------------------
# zero-shot suite
# ---------------------------------------------------------------------------

TASKS = ("piqa", "winogrande", "hellaswag", "arc_easy", "arc_challenge")


def _make_task_items(
    task: str, n_items: int, prefix_len: int, cont_len: int, seed: int
) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Build ``(prefixes, true_conts, distractor_conts)`` for one task."""
    r = np.random.default_rng(hash(task) % 2**31 + seed)
    total = prefix_len + cont_len
    corpus = data.make_corpus("wikitext2", (n_items + 4) * (total + 1) + total, seed=seed + 7)
    windows = data.batches(corpus, n_items * 2, total, seed=seed + 13)
    pre = windows[:n_items, :prefix_len]
    true = windows[:n_items, prefix_len:total]
    other = windows[n_items:, prefix_len:total]  # text from elsewhere

    if task == "piqa":
        # plausible-vs-implausible: distractor is fully random tokens
        distract = r.integers(0, data.VOCAB_SIZE, size=true.shape).astype(np.int32)
    elif task == "winogrande":
        # minimal perturbation: reverse the continuation
        distract = true[:, ::-1].copy()
    elif task == "hellaswag":
        # wrong-but-fluent: continuation lifted from another context
        distract = other
    elif task == "arc_easy":
        # shuffled continuation (same tokens, broken order)
        distract = true.copy()
        for row in distract:
            r.shuffle(row)
    elif task == "arc_challenge":
        # hard: true continuation with 25% of tokens resampled
        distract = true.copy()
        mask = r.random(true.shape) < 0.25
        distract[mask] = r.integers(0, data.VOCAB_SIZE, size=int(mask.sum()))
    else:
        raise KeyError(task)
    return pre, true, distract


def _continuation_score(forward, prefix, cont, batch=16) -> np.ndarray:
    """Total log-likelihood of each continuation given its prefix."""
    full = np.concatenate([prefix, cont], axis=1)
    logp = _log_probs(forward, full, batch=batch)  # positions 0..S-1
    cont_start = prefix.shape[1] - 1  # logp index predicting cont[0]
    return logp[:, cont_start:].sum(axis=1)


def zero_shot_accuracy(
    forward, task: str, n_items: int = 64, prefix_len: int = 48,
    cont_len: int = 16, seed: int = 0,
) -> float:
    """Accuracy of picking the true continuation over the distractor."""
    pre, true, distract = _make_task_items(task, n_items, prefix_len, cont_len, seed)
    s_true = _continuation_score(forward, pre, true)
    s_false = _continuation_score(forward, pre, distract)
    # ties (e.g. a constant scorer) count half — standard MC treatment
    return float(np.mean((s_true > s_false) + 0.5 * (s_true == s_false)))


def zero_shot_suite(forward, n_items: int = 64, seed: int = 0) -> dict[str, float]:
    """All five tasks + average (the Table 3 row for one model)."""
    accs = {t: zero_shot_accuracy(forward, t, n_items=n_items, seed=seed) for t in TASKS}
    accs["avg"] = float(np.mean([accs[t] for t in TASKS]))
    return accs


# ---------------------------------------------------------------------------
# Figure 10 diagnostic
# ---------------------------------------------------------------------------


def activation_variance_by_layer(
    params, cfg, n_seq: int = 8, seq: int = 128, seed: int = 0
) -> dict[str, float]:
    """Mean input variance per linear-layer *kind*, averaged over blocks.

    Reproduces Figure 10's observation: the ``down_proj``/``fc2`` input
    variance dwarfs the other layers' (SwiGLU Hadamard-product effect).
    """
    calib = data.calibration_sequences("pile", n_seq, seq, seed=seed)[:, :-1]
    store: dict[str, list] = {}
    apply = common.make_capture_apply(store)
    common.forward(params, jnp.asarray(calib), cfg, apply_linear=apply)
    by_kind: dict[str, list] = {}
    for name, chunks in store.items():
        kind = name.split(".")[-1]
        x = np.concatenate(chunks, axis=0)
        by_kind.setdefault(kind, []).append(float(np.var(x)))
    return {k: float(np.mean(v)) for k, v in by_kind.items()}
