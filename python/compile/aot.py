"""AOT export: lower the model forwards to HLO text + weight blobs.

This is the only bridge between the Python build path and the Rust serving
runtime.  For each exported artifact we emit:

* ``<name>.hlo.txt`` — HLO **text** (NOT a serialized ``HloModuleProto``:
  jax ≥ 0.5 emits 64-bit instruction ids which xla_extension 0.5.1 rejects;
  the text parser reassigns ids — see /opt/xla-example/README.md);
* ``<name>.weights.bin`` — the flattened weight leaves as one raw
  little-endian blob, with per-leaf (name, dtype, shape, offset) records in
  the manifest.  Weights are HLO *parameters*, not constants, so artifacts
  stay small and one HLO serves any checkpoint with the same shapes;
* a ``manifest.json`` entry describing parameter order, runtime inputs
  (tokens / KV-cache buffers / cache_len) and outputs.

Exported signatures (one per (variant, batch) combination)::

    prefill/decode: (weights..., tokens[B,S], cache_k, cache_v, cache_len)
                    → (logits[B,S,V], cache_k', cache_v')

Prefill is just the ``S = prompt_len, cache_len = 0`` instance; decode is
``S = 1``.  The Rust coordinator owns the cache buffers and threads them
through consecutive calls (zero-copy on CPU PJRT aside, the interface is
the paper's "unified single-token and multi-token inference" future-work
point made concrete).

Golden files: for every artifact we also run the lowered function in
Python on fixed inputs and store input/output arrays, so the Rust runtime
has an exact end-to-end numeric check (``rust/tests/runtime_golden.rs``).
"""

from __future__ import annotations

import argparse
import json
import pathlib

import numpy as np
import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import data as data_mod
from . import model as model_mod
from . import train as train_mod
from .modeling import common, presets
from .quik import policy as policy_mod
from .kernels.ref import QuantizedWeights
from .kernels import quik_linear as quik_linear_mod
from .kernels.ref import quik_linear_ref

REPO = pathlib.Path(__file__).resolve().parents[2]
DEFAULT_OUT = REPO / "artifacts"

_DTYPES = {"float32": "f32", "int32": "s32", "int8": "s8"}


def to_hlo_text(lowered) -> str:
    """StableHLO → XlaComputation → HLO text (the interchange format)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


# ---------------------------------------------------------------------------
# export trees for FP16 and quantized models
# ---------------------------------------------------------------------------


def fp16_export_tree(params: common.Params):
    """The FP16 artifact simply ships the full parameter pytree."""
    return params, {}


def quik_export_tree(qm: model_mod.QuantizedModel):
    """Split a QuantizedModel into (traced pytree, static metadata).

    The pytree carries every runtime array (int8 weights, FP outlier
    columns, scales, permutations, biases, SmoothQuant scales and the
    non-linear base params with quantized FP weights stripped); the static
    metadata records per-layer bit widths — everything the traced apply
    callback needs that must be a Python constant.
    """
    base = {
        "embed": qm.params["embed"],
        "final_norm": qm.params["final_norm"],
        "layers": [],
    }
    if "pos_embed" in qm.params:
        base["pos_embed"] = qm.params["pos_embed"]
    q: dict[str, dict] = {}
    meta: dict[str, dict] = {}
    for li, lp in enumerate(qm.params["layers"]):
        slot: dict = {"attn_norm": lp["attn_norm"]}
        if "mlp_norm" in lp:
            slot["mlp_norm"] = lp["mlp_norm"]
        for lname in qm.cfg.linear_names():
            section = "self_attn" if lname[0] in "qkvo" else "mlp"
            full = f"layers.{li}.{section}.{lname}"
            ql = qm.qlayers[full]
            if ql.scheme == "fp16":
                slot[lname] = {"w": ql.w_fp16} | (
                    {"b": ql.bias} if ql.bias is not None else {}
                )
                continue
            slot[lname] = {}  # quantized: no FP weight in the artifact
            entry: dict = {
                "w_int": ql.qw.w_int,
                "w_fp": ql.qw.w_fp,
                "scale_w": ql.qw.scale_w,
                "w_reduced": ql.qw.w_reduced,
            }
            if ql.perm is not None:
                entry["perm"] = jnp.asarray(ql.perm, jnp.int32)
            if ql.bias is not None:
                entry["bias"] = ql.bias
            if ql.smooth_scale is not None:
                entry["smooth"] = jnp.asarray(ql.smooth_scale)
            q[full] = entry
            meta[full] = {
                "weight_bits": ql.plan.weight_bits,
                "act_bits": ql.plan.act_bits,
            }
        base["layers"].append(slot)
    return {"base": base, "q": q}, meta


def make_export_apply(qtree: dict, meta: dict, use_kernels: bool) -> common.ApplyLinear:
    """Traced quantized-linear callback used inside the lowered function."""

    def apply(name: str, x: jnp.ndarray, p: common.Params) -> jnp.ndarray:
        e = qtree.get(name)
        if e is None:
            y = jnp.matmul(x, p["w"].T)
            return y + p["b"] if "b" in p else y
        if "smooth" in e:
            x = x / e["smooth"][None, :]
        if "perm" in e:
            x = x[:, e["perm"]]
        qw = QuantizedWeights(
            w_int=e["w_int"], w_fp=e["w_fp"], scale_w=e["scale_w"],
            w_reduced=e["w_reduced"], bits=meta[name]["weight_bits"],
        )
        bias = e.get("bias")
        act_bits = meta[name]["act_bits"]
        if use_kernels:
            return quik_linear_mod.quik_linear(
                x, qw, bias, version=3, act_bits=act_bits
            )
        return quik_linear_ref(x, qw, bias, act_bits=act_bits)

    return apply


# ---------------------------------------------------------------------------
# artifact writer
# ---------------------------------------------------------------------------


def _leaf_records(tree) -> list[tuple[str, np.ndarray]]:
    """Flatten a pytree into (dotted-path, array) leaves in traversal order."""
    leaves = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in leaves:
        name = ".".join(
            str(getattr(k, "key", getattr(k, "idx", k))) for k in path
        )
        out.append((name, np.asarray(leaf)))
    return out


def export_artifact(
    name: str,
    cfg: common.ModelConfig,
    weights_tree,
    meta: dict | None,
    batch: int,
    seq: int,
    out_dir: pathlib.Path,
    use_kernels: bool = False,
    golden_seed: int = 0,
) -> dict:
    """Lower one (variant, batch, seq) forward; write hlo/weights/golden.

    ``meta`` non-None marks a quantized tree (``{"base": ..., "q": ...}``);
    the apply callback is built *inside* the traced function from the traced
    weights argument, so every quantized array is an HLO parameter (never a
    baked constant).
    """
    t_max = cfg.max_seq
    cache_shape = (cfg.n_layers, batch, cfg.n_heads, t_max, cfg.d_head)

    def fn(weights, tokens, cache_k, cache_v, cache_len):
        if meta is None:
            base, apply = weights, common._default_apply
        else:
            base = weights["base"]
            apply = make_export_apply(weights["q"], meta, use_kernels)
        return common.forward_with_cache(
            base, tokens, cfg, cache_k, cache_v, cache_len, apply_linear=apply,
        )

    specs = (
        jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), weights_tree),
        jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        jax.ShapeDtypeStruct(cache_shape, jnp.float32),
        jax.ShapeDtypeStruct(cache_shape, jnp.float32),
        jax.ShapeDtypeStruct((), jnp.int32),
    )
    lowered = jax.jit(fn).lower(*specs)
    hlo_path = out_dir / f"{name}.hlo.txt"
    hlo_path.write_text(to_hlo_text(lowered))

    # Weight blob + per-leaf records (this order == HLO parameter order,
    # since jit flattens arguments in pytree traversal order).
    records = _leaf_records(weights_tree)
    blob_path = out_dir / f"{name}.weights.bin"
    params_meta = []
    with open(blob_path, "wb") as f:
        offset = 0
        for pname, arr in records:
            raw = np.ascontiguousarray(arr).tobytes()
            params_meta.append({
                "name": pname,
                "dtype": _DTYPES[str(arr.dtype)],
                "shape": list(arr.shape),
                "offset": offset,
                "nbytes": len(raw),
            })
            f.write(raw)
            offset += len(raw)

    # Golden run: prefill on fixed tokens, then one decode step.
    r = np.random.default_rng(golden_seed)
    tokens = r.integers(0, cfg.vocab, size=(batch, seq)).astype(np.int32)
    ck = jnp.zeros(cache_shape, jnp.float32)
    cv = jnp.zeros(cache_shape, jnp.float32)
    logits, ck1, cv1 = fn(weights_tree, jnp.asarray(tokens), ck, cv, jnp.int32(0))
    golden_path = out_dir / f"{name}.golden.bin"
    with open(golden_path, "wb") as f:
        f.write(np.ascontiguousarray(tokens).tobytes())
        f.write(np.ascontiguousarray(np.asarray(logits, np.float32)).tobytes())
    golden = {
        "tokens_shape": [batch, seq],
        "logits_shape": list(logits.shape),
        "file": golden_path.name,
    }

    return {
        "hlo": hlo_path.name,
        "weights": blob_path.name,
        "params": params_meta,
        "inputs": [
            {"name": "tokens", "dtype": "s32", "shape": [batch, seq]},
            {"name": "cache_k", "dtype": "f32", "shape": list(cache_shape)},
            {"name": "cache_v", "dtype": "f32", "shape": list(cache_shape)},
            {"name": "cache_len", "dtype": "s32", "shape": []},
        ],
        "outputs": [
            {"name": "logits", "dtype": "f32", "shape": [batch, seq, cfg.vocab]},
            {"name": "cache_k", "dtype": "f32", "shape": list(cache_shape)},
            {"name": "cache_v", "dtype": "f32", "shape": list(cache_shape)},
        ],
        "golden": golden,
        "batch": batch,
        "seq": seq,
    }


# ---------------------------------------------------------------------------
# top-level build
# ---------------------------------------------------------------------------


def build_model_artifacts(
    model_name: str,
    out_dir: pathlib.Path,
    train_steps: int = 300,
    prefill_seq: int = 64,
    batches: tuple[int, ...] = (1, 4),
    kernel_variant: bool = True,
) -> dict:
    """Train + quantize one tiny model and export all its artifacts."""
    cfg, params, losses = train_mod.load_or_train(model_name, steps=train_steps)
    calib = data_mod.calibration_sequences("pile", 64, 128, seed=1)[:, :-1]
    calib_inputs = model_mod.calibrate(params, cfg, calib)
    pol = policy_mod.QuikPolicy(n_outlier=presets.tiny_outliers(cfg))
    qm = model_mod.quantize_model(params, cfg, calib_inputs, pol, scheme="quik")

    fp_tree, _ = fp16_export_tree(params)
    q_tree, q_meta = quik_export_tree(qm)

    artifacts = {}
    for b in batches:
        artifacts[f"fp16_prefill_b{b}"] = export_artifact(
            f"{model_name}_fp16_prefill_b{b}", cfg, fp_tree, None,
            b, prefill_seq, out_dir,
        )
        artifacts[f"fp16_decode_b{b}"] = export_artifact(
            f"{model_name}_fp16_decode_b{b}", cfg, fp_tree, None,
            b, 1, out_dir,
        )
        artifacts[f"quik4_prefill_b{b}"] = export_artifact(
            f"{model_name}_quik4_prefill_b{b}", cfg, q_tree, q_meta,
            b, prefill_seq, out_dir,
        )
        artifacts[f"quik4_decode_b{b}"] = export_artifact(
            f"{model_name}_quik4_decode_b{b}", cfg, q_tree, q_meta,
            b, 1, out_dir,
        )
    # Speculative-decoding support (the paper's future-work §5): a
    # "verify" artifact scores K draft tokens in one call — same cached
    # forward, S_new = K.  QUIK-4B drafts with decode_b1; FP16 verifies.
    spec_k = 4
    artifacts["fp16_verify_b1"] = export_artifact(
        f"{model_name}_fp16_verify_b1", cfg, fp_tree, None,
        1, spec_k, out_dir,
    )
    artifacts["quik4_verify_b1"] = export_artifact(
        f"{model_name}_quik4_verify_b1", cfg, q_tree, q_meta,
        1, spec_k, out_dir,
    )
    if kernel_variant:
        # Pallas-kernel lowering proof: the fused QUIK kernels inside the
        # same HLO (interpret-mode grids become HLO loops — slower to run,
        # numerically identical; the runtime test checks it against quik4).
        artifacts["quik4_kernels_prefill_b1"] = export_artifact(
            f"{model_name}_quik4_kernels_prefill_b1", cfg, q_tree, q_meta,
            1, 16, out_dir, use_kernels=True,
        )

    return {
        "config": {
            "family": cfg.family, "vocab": cfg.vocab, "d_model": cfg.d_model,
            "n_layers": cfg.n_layers, "n_heads": cfg.n_heads, "d_ff": cfg.d_ff,
            "max_seq": cfg.max_seq,
        },
        "train_final_loss": losses[-1] if losses else None,
        "artifacts": artifacts,
    }


def write_quant_goldens(out_dir: pathlib.Path) -> None:
    """Cross-language golden vectors: the Rust quant substrate must match
    the Python oracle bit-for-bit on these (rust/tests/quant_substrate.rs).
    """
    from .kernels import ref as kref

    r = np.random.default_rng(20240501)
    m, k, n = 4, 16, 6
    x = (r.normal(size=(m, k)) * 3).astype(np.float32)
    w = r.normal(size=(n, k)).astype(np.float32)
    golden: dict = {"m": m, "k": k, "n": n, "x": x.flatten().tolist(),
                    "w": w.flatten().tolist(), "cases": {}}
    for bits in (4, 8):
        qa = kref.quantize_acts_ref(jnp.asarray(x), bits)
        qw = kref.quantize_weights_ref(jnp.asarray(w), bits, 0)
        acc = kref.int_matmul_ref(qa.q, qw.w_int)
        y = kref.dequantize_ref(acc, qa.scale, qa.zero, qw.scale_w,
                                qw.w_reduced, bits)
        golden["cases"][str(bits)] = {
            "q": np.asarray(qa.q).flatten().astype(int).tolist(),
            "scale": np.asarray(qa.scale).tolist(),
            "zero": np.asarray(qa.zero).tolist(),
            "w_int": np.asarray(qw.w_int).flatten().astype(int).tolist(),
            "scale_w": np.asarray(qw.scale_w).tolist(),
            "w_reduced": np.asarray(qw.w_reduced).tolist(),
            "acc": np.asarray(acc).flatten().astype(int).tolist(),
            "y": np.asarray(y).flatten().tolist(),
        }
    (out_dir / "quant_golden.json").write_text(json.dumps(golden))


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", type=pathlib.Path, default=DEFAULT_OUT)
    ap.add_argument("--models", nargs="*", default=["llama-s"])
    ap.add_argument("--train-steps", type=int, default=300)
    ap.add_argument("--no-kernel-variant", action="store_true")
    args = ap.parse_args()
    out_dir = args.out
    out_dir.mkdir(parents=True, exist_ok=True)

    manifest = {"models": {}}
    for name in args.models:
        print(f"[aot] building artifacts for {name}")
        manifest["models"][name] = build_model_artifacts(
            name, out_dir, train_steps=args.train_steps,
            kernel_variant=not args.no_kernel_variant,
        )

    # Paper-scale shape table for Rust device/memory model parity tests.
    (out_dir / "model_zoo.json").write_text(
        json.dumps(presets.PAPER_SCALE, indent=1)
    )
    write_quant_goldens(out_dir)
    (out_dir / "manifest.json").write_text(json.dumps(manifest, indent=1))
    print(f"[aot] wrote {out_dir}/manifest.json")


if __name__ == "__main__":
    main()
