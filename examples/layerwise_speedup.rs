//! Layer-wise speedup explorer (Figure 7): sweep QUIK configurations over
//! LLaMA-shaped linear layers on the calibrated RTX 3090 device model and
//! print who wins where — including the fusion-version ablation (Fig. 6).

use quik::config::{LayerPlan, QuikPolicy};
use quik::devicemodel::gpu::RTX3090;
use quik::devicemodel::layer::{FusionVersion, QuikLayerModel};

fn main() {
    let g = RTX3090;
    let m = 2048;
    println!("QUIK-4B layer speedups vs FP16 ({} tokens, {}):\n", m, g.name);
    println!("{:<16} {:>8} {:>8} {:>8}", "layer k->n", "v1", "v2", "v3");
    for (k, n) in [
        (2048usize, 2048usize),
        (4096, 4096),
        (8192, 8192),
        (8192, 28672),
    ] {
        let l = QuikLayerModel::new(k, n, QuikPolicy::QUIK_4B.plan_for("q_proj", k));
        let s = |v| l.speedup(&g, m, v);
        println!(
            "{:<16} {:>7.2}x {:>7.2}x {:>7.2}x",
            format!("{k}->{n}"),
            s(FusionVersion::V1Unfused),
            s(FusionVersion::V2FusedQuant),
            s(FusionVersion::V3FusedBoth)
        );
    }

    println!("\noutlier-count sensitivity on 8192->8192 (v3, us):");
    for n_out in [0usize, 128, 256, 512, 1024] {
        let plan = LayerPlan { n_outlier: n_out, ..QuikPolicy::QUIK_4B.plan_for("q_proj", 8192) };
        let l = QuikLayerModel::new(8192, 8192, plan);
        println!(
            "  {n_out:>5} outliers: {:>7.1} us",
            l.quik_time(&g, m, FusionVersion::V3FusedBoth).total() * 1e6
        );
    }
    println!("\n(shape: outliers nearly free — the paper's Fig. 14)");
}
