//! Memory planning (Table 6): how much memory each model needs under
//! FP16 / QUIK-8B / QUIK-4B, its byte-level composition, and how many
//! RTX 3090s a deployment takes — the paper's Falcon-180B story.

use quik::config::{model_zoo, spec, QuikPolicy};
use quik::devicemodel::gpu::RTX3090;
use quik::memmodel::{memory_report, table6_row};

fn main() {
    println!("peak memory (GB), batch 1 x seq 2048 prefill\n");
    println!("{:<13} {:>8} {:>8} {:>8} {:>6}", "model", "FP16", "Q8", "Q4", "GPUs");
    for (name, s) in model_zoo() {
        let [fp16, q8, q4] = table6_row(&s, 1, 2048);
        let gpus = (q4 * 1e9 / (RTX3090.mem_capacity * 0.9)).ceil();
        println!("{name:<13} {fp16:>8.1} {q8:>8.1} {q4:>8.1} {gpus:>6.0}");
    }

    println!("\nLLaMA2-70B QUIK-4B composition:");
    let r = memory_report(&spec("llama2-70b").unwrap(), &QuikPolicy::QUIK_4B, 1, 2048);
    for (label, bytes) in [
        ("quantized weights", r.weight_bytes),
        ("FP16 outlier columns", r.outlier_bytes),
        ("scales/metadata", r.metadata_bytes),
        ("embeddings + head", r.embedding_bytes),
        ("activations", r.activation_bytes),
        ("KV cache (2048 ctx)", r.kv_cache_bytes),
    ] {
        println!("  {label:<22} {:>8.2} GB", bytes / 1e9);
    }
    println!("  {:<22} {:>8.2} GB  (paper: 49.1 GB, <50 GB headline)", "total", r.total_gb());

    println!("\nFalcon-180B: FP16 {:.0} GB exceeds an 8x3090 server (192 GB);", table6_row(&spec("falcon-180b").unwrap(), 1, 2048)[0]);
    println!("QUIK-4B brings it to {:.0} GB — single-server deployment.", table6_row(&spec("falcon-180b").unwrap(), 1, 2048)[2]);
}
