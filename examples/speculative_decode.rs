//! Speculative decoding demo (the paper's §5 future work made concrete):
//! QUIK-4B drafts, FP16 verifies in K-token windows, and the emitted
//! stream is provably the FP16 greedy stream — compared against plain
//! FP16 decode for both correctness and target-call savings.

use anyhow::Result;
use quik::coordinator::speculative::SpeculativeDecoder;
use quik::runtime::engine::ModelRuntime;
use quik::util::rng::Rng;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n_gen = 32;
    let mut rt = ModelRuntime::load(&artifacts, "llama-s")?;
    SpeculativeDecoder::load_artifacts(&mut rt)?;
    rt.ensure_loaded("fp16_decode_b1")?;

    let prefill = rt.artifact("fp16_prefill_b1").unwrap();
    let mut rng = Rng::new(99);
    let prompt: Vec<i32> = (0..prefill.spec.seq).map(|_| rng.range_i32(0, 255)).collect();

    // --- plain FP16 greedy reference ---
    let t0 = std::time::Instant::now();
    let mut cache = prefill.new_cache()?;
    let out = prefill.run(&prompt, &mut cache)?;
    let mut tok = out.argmax_last()[0];
    let decode = rt.artifact("fp16_decode_b1").unwrap();
    let mut reference = vec![tok];
    for _ in 0..n_gen - 1 {
        let step = decode.run(&[tok], &mut cache)?;
        tok = step.argmax_last()[0];
        reference.push(tok);
    }
    let t_plain = t0.elapsed();

    // --- speculative: QUIK-4B draft + FP16 verify ---
    let spec = SpeculativeDecoder::new(&rt)?;
    let t1 = std::time::Instant::now();
    let (tokens, stats) = spec.generate(&prompt, n_gen)?;
    let t_spec = t1.elapsed();

    println!("plain FP16 : {reference:?}  ({t_plain:.2?})");
    println!("speculative: {tokens:?}  ({t_spec:.2?})");
    println!(
        "match: {}   acceptance {:.0}%   {:.2} tokens/target-call ({} target calls vs {} plain)",
        tokens == reference,
        stats.acceptance_rate() * 100.0,
        stats.tokens_per_target_call(tokens.len()),
        stats.target_calls,
        n_gen
    );
    Ok(())
}
