//! Speculative decoding demo (the paper's §5 future work made concrete):
//! QUIK-4B drafts, the FP32 reference verifies in K-token windows, and
//! the emitted stream is provably the reference greedy stream — compared
//! against plain reference decode for both correctness and target-call
//! savings.  Runs entirely on the native backend.

use anyhow::Result;
use quik::backend::native::{demo_policy, NativeBackend, NativeConfig};
use quik::backend::{InferenceBackend, Phase, Variant};
use quik::coordinator::speculative::SpeculativeDecoder;
use quik::util::rng::Rng;

fn main() -> Result<()> {
    let n_gen = 32;
    let mut backend =
        NativeBackend::seeded("spec-decode", NativeConfig::demo(), 5, demo_policy())?;
    SpeculativeDecoder::prepare(&mut backend)?;

    let mut rng = Rng::new(99);
    let prompt: Vec<i32> =
        (0..24).map(|_| rng.range_i32(0, backend.vocab() as i32 - 1)).collect();

    // --- plain FP32 greedy reference ---
    let t0 = std::time::Instant::now();
    let mut cache = backend.new_cache(Variant::Fp16, 1)?;
    let out = backend.forward(Variant::Fp16, Phase::Prefill, &prompt, 1, &mut cache)?;
    let mut tok = out.argmax_last()[0];
    let mut reference = vec![tok];
    for _ in 0..n_gen - 1 {
        let step = backend.forward(Variant::Fp16, Phase::Decode, &[tok], 1, &mut cache)?;
        tok = step.argmax_last()[0];
        reference.push(tok);
    }
    let t_plain = t0.elapsed();

    // --- speculative: QUIK-4B draft + FP32 verify ---
    let spec = SpeculativeDecoder::new(&backend)?;
    let t1 = std::time::Instant::now();
    let (tokens, stats) = spec.generate(&prompt, n_gen)?;
    let t_spec = t1.elapsed();

    println!("plain FP32 : {reference:?}  ({t_plain:.2?})");
    println!("speculative: {tokens:?}  ({t_spec:.2?})");
    println!(
        "match: {}   acceptance {:.0}%   {:.2} tokens/target-call ({} target calls vs {} plain)",
        tokens == reference,
        stats.acceptance_rate() * 100.0,
        stats.tokens_per_target_call(tokens.len()),
        stats.target_calls,
        n_gen
    );
    Ok(())
}
