//! Quickstart: the smallest end-to-end slice of the native QUIK engine.
//!
//! Builds a seeded FP32 checkpoint, quantizes every backbone linear
//! through the QUIK pipeline at startup (calibration → outlier selection
//! → nibble-packed INT4), then runs one prefill step on both the FP32
//! reference and the QUIK-4B stack and compares their greedy choices.
//! No Python, no artifacts, no XLA:
//!
//! ```sh
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use quik::backend::native::{demo_policy, NativeBackend, NativeConfig};
use quik::backend::{InferenceBackend, Phase, Variant};

fn main() -> Result<()> {
    // 1. Seeded checkpoint + QUIK quantization at startup.
    let mut backend = NativeBackend::seeded("quickstart", NativeConfig::demo(), 5, demo_policy())?;
    println!("variants: {:?}", backend.variants());
    backend.prepare(Variant::Quik4, Phase::Prefill, 1)?;
    println!(
        "quantized weight storage: {} bytes (vs {} bytes FP32 backbone)",
        backend.quik_storage_bytes().unwrap(),
        backend.fp32_linear_bytes()
    );

    // 2. Run a prefill over a toy prompt on both variants.
    let vocab = backend.vocab() as i32;
    let prompt: Vec<i32> = (0..24).map(|i| (i * 17 + 3) % vocab).collect();
    let mut choices = vec![];
    for variant in [Variant::Fp16, Variant::Quik4] {
        let mut cache = backend.new_cache(variant, 1)?;
        let out = backend.forward(variant, Phase::Prefill, &prompt, 1, &mut cache)?;
        println!(
            "{variant:?}: logits [{} x {} x {}], greedy next token {}",
            out.batch,
            out.seq,
            out.vocab,
            out.argmax_last()[0]
        );
        choices.push(out.argmax_last()[0]);
    }

    // 3. On the outlier-planted demo model the hybrid INT4 format keeps
    //    the greedy choice.
    println!(
        "FP32 and QUIK-4B {}",
        if choices[0] == choices[1] { "agree" } else { "differ" }
    );
    Ok(())
}
