//! Quickstart: load a QUIK AOT artifact, run one prefill call through
//! PJRT, and inspect the output — the smallest end-to-end slice of the
//! three-layer stack.
//!
//! ```sh
//! make artifacts          # once: trains + quantizes + AOT-lowers
//! cargo run --release --example quickstart
//! ```

use anyhow::Result;
use quik::runtime::engine::ModelRuntime;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());

    // 1. Load the manifest and compile the QUIK-4B prefill program.
    let mut rt = ModelRuntime::load(&artifacts, "llama-s")?;
    println!("available variants: {:?}", rt.variants());
    rt.ensure_loaded("quik4_prefill_b1")?;
    let art = rt.artifact("quik4_prefill_b1").unwrap();
    println!(
        "loaded quik4_prefill_b1: batch={} seq={} ({} weight tensors)",
        art.spec.batch,
        art.spec.seq,
        art.spec.params.len()
    );

    // 2. Run a prefill over a toy prompt (token ids mod vocab).
    let seq = art.spec.seq;
    let prompt: Vec<i32> = (0..seq as i32).map(|i| (i * 17 + 3) % 250).collect();
    let mut cache = art.new_cache()?;
    let out = art.run(&prompt, &mut cache)?;

    // 3. Inspect: logits shape and the greedy next token.
    println!(
        "logits: [{} x {} x {}], cache now at position {}",
        out.batch, out.seq, out.vocab, cache.cache_len
    );
    println!("greedy next token: {}", out.argmax_last()[0]);

    // 4. The same artifact exists in FP16 — compare the predictions.
    rt.ensure_loaded("fp16_prefill_b1")?;
    let fp = rt.artifact("fp16_prefill_b1").unwrap();
    let mut fp_cache = fp.new_cache()?;
    let fp_out = fp.run(&prompt, &mut fp_cache)?;
    println!(
        "FP16 next token: {} (QUIK-4B and FP16 {})",
        fp_out.argmax_last()[0],
        if fp_out.argmax_last() == out.argmax_last() { "agree" } else { "differ" }
    );
    Ok(())
}
