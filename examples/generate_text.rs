//! Greedy generation through the native QUIK engine: prefill a prompt,
//! then stream decode steps, comparing the FP32-reference and QUIK-4B
//! token streams (hybrid quantization rarely flips greedy choices on an
//! outlier-calibrated model).

use anyhow::Result;
use quik::backend::native::{demo_policy, NativeBackend, NativeConfig};
use quik::backend::{InferenceBackend, Phase, Variant};
use quik::util::rng::Rng;

fn main() -> Result<()> {
    let n_gen = 24;
    let mut backend =
        NativeBackend::seeded("generate-text", NativeConfig::demo(), 5, demo_policy())?;
    backend.prepare(Variant::Quik4, Phase::Prefill, 1)?;

    let mut streams = vec![];
    for variant in [Variant::Fp16, Variant::Quik4] {
        let mut rng = Rng::new(2024);
        let prompt: Vec<i32> =
            (0..24).map(|_| rng.range_i32(0, backend.vocab() as i32 - 1)).collect();
        let mut cache = backend.new_cache(variant, 1)?;
        let out = backend.forward(variant, Phase::Prefill, &prompt, 1, &mut cache)?;
        let mut tok = out.argmax_last()[0];
        let mut stream = vec![tok];
        for _ in 0..n_gen - 1 {
            let step = backend.forward(variant, Phase::Decode, &[tok], 1, &mut cache)?;
            tok = step.argmax_last()[0];
            stream.push(tok);
        }
        println!("{:>6}: {stream:?}", variant.prefix());
        streams.push(stream);
    }
    let agree = streams[0]
        .iter()
        .zip(&streams[1])
        .filter(|(a, b)| a == b)
        .count();
    println!("agreement: {agree}/{n_gen} greedy tokens");
    Ok(())
}
