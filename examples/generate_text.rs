//! Greedy generation through the QUIK-4B artifact: prefill a prompt from
//! the synthetic corpus distribution, then stream decode steps, comparing
//! the FP16 and QUIK token streams (quantization rarely flips greedy
//! choices on a well-calibrated model).

use anyhow::Result;
use quik::runtime::engine::ModelRuntime;
use quik::util::rng::Rng;

fn main() -> Result<()> {
    let artifacts = std::env::args().nth(1).unwrap_or_else(|| "artifacts".into());
    let n_gen = 24;
    let mut rt = ModelRuntime::load(&artifacts, "llama-s")?;

    let mut streams = vec![];
    for variant in ["fp16", "quik4"] {
        let prefill_name = format!("{variant}_prefill_b1");
        let decode_name = format!("{variant}_decode_b1");
        rt.ensure_loaded(&prefill_name)?;
        rt.ensure_loaded(&decode_name)?;
        let prefill = rt.artifact(&prefill_name).unwrap();
        let mut rng = Rng::new(2024);
        let prompt: Vec<i32> =
            (0..prefill.spec.seq).map(|_| rng.range_i32(0, 255)).collect();
        let mut cache = prefill.new_cache()?;
        let out = prefill.run(&prompt, &mut cache)?;
        let mut tok = out.argmax_last()[0];
        let decode = rt.artifact(&decode_name).unwrap();
        let mut stream = vec![tok];
        for _ in 0..n_gen - 1 {
            let step = decode.run(&[tok], &mut cache)?;
            tok = step.argmax_last()[0];
            stream.push(tok);
        }
        println!("{variant:>6}: {stream:?}");
        streams.push(stream);
    }
    let agree = streams[0]
        .iter()
        .zip(&streams[1])
        .filter(|(a, b)| a == b)
        .count();
    println!("agreement: {agree}/{n_gen} greedy tokens");
    Ok(())
}
